//! Round-level training event stream: a bounded-queue, off-hot-path sink.
//!
//! The paper's scaling claims rest on thousand-job `(t, y)` training grids
//! where per-round visibility is the difference between diagnosing one slow
//! slot and re-running the whole grid. This module is the transport: emitters
//! (the boosting loop, the coordinator's job slots, the sampler service)
//! serialize [`Event`]s through a bounded [`std::sync::mpsc`] channel to a
//! single writer thread that owns the output file.
//!
//! The contract is **never block a boosting round**: [`EventSink::emit`] is
//! one `try_send` — if the queue is full (slow disk, dead pipe) the event is
//! dropped and counted in [`EventSink::dropped_events`], and training
//! proceeds bit-identically either way. Dropping the sink closes the channel
//! and joins the writer, so the log file is complete when the owner returns.
//!
//! Two wire formats, chosen by file extension (`.csv` → CSV with a fixed
//! union-column header, anything else → JSONL via [`crate::util::json`]).

use crate::util::json::Json;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

/// Queue capacity for file-backed sinks: deep enough to absorb bursty
/// multi-job rounds, small enough that a wedged disk bounds memory.
pub const DEFAULT_QUEUE_EVENTS: usize = 65_536;

/// The writer flushes its buffer every this many events, so a tail -f on the
/// log sees progress at round granularity without a syscall per event.
const FLUSH_EVERY: usize = 64;

/// Fixed union-column CSV header; inapplicable fields are left empty so every
/// row has the same arity regardless of event kind.
pub const CSV_HEADER: &str = "type,t_idx,y,round,attempt,phase,objective,train_loss,\
eval_loss,round_wall_ms,rounds_trained,queue_depth,requests_served,batches_run,\
max_coalesced,detail";

/// Wire format of an event log.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventFormat {
    /// One compact JSON object per line (the default).
    Jsonl,
    /// Fixed-arity rows under [`CSV_HEADER`]; `detail` is quoted when needed.
    Csv,
}

impl EventFormat {
    /// Choose the format from a path: `.csv` means CSV, everything else JSONL.
    pub fn for_path(path: &Path) -> EventFormat {
        match path.extension().and_then(|e| e.to_str()) {
            Some("csv") => EventFormat::Csv,
            _ => EventFormat::Jsonl,
        }
    }
}

/// Lifecycle phase of a coordinator job slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    /// An attempt began (one per retry, so `attempt` disambiguates).
    Started,
    /// The job finished and its ensemble was kept.
    Completed,
    /// An attempt failed and the slot is backing off before the next one.
    Retried,
    /// Retries are exhausted; the slot is recorded as a `JobFailure`.
    Failed,
    /// The job hit the run's wall-clock deadline and stopped early (it still
    /// completes with a truncated ensemble; a `Completed` event follows).
    DeadlineStopped,
}

impl JobPhase {
    /// Stable lowercase name used on the wire.
    pub fn name(self) -> &'static str {
        match self {
            JobPhase::Started => "started",
            JobPhase::Completed => "completed",
            JobPhase::Retried => "retried",
            JobPhase::Failed => "failed",
            JobPhase::DeadlineStopped => "deadline_stopped",
        }
    }
}

/// One boosting round of one `(t, y)` job.
#[derive(Clone, Debug)]
pub struct TrainRoundEvent {
    pub t_idx: usize,
    pub y: usize,
    pub round: usize,
    pub objective: &'static str,
    pub train_loss: f64,
    /// `None` when the job trains without a validation split.
    pub eval_loss: Option<f64>,
    pub round_wall_ms: f64,
}

/// A job-slot lifecycle transition in the coordinator.
#[derive(Clone, Debug)]
pub struct JobEvent {
    pub t_idx: usize,
    pub y: usize,
    pub phase: JobPhase,
    pub attempt: usize,
    /// Rounds actually trained; meaningful for `Completed`/`DeadlineStopped`.
    pub rounds_trained: usize,
    /// Failure cause for `Retried`/`Failed`; empty otherwise.
    pub detail: String,
}

/// A point-in-time snapshot of the sampler service counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceGauge {
    pub queue_depth: usize,
    pub requests_served: usize,
    pub batches_run: usize,
    pub max_coalesced: usize,
}

/// Anything the sink can carry.
#[derive(Clone, Debug)]
pub enum Event {
    Round(TrainRoundEvent),
    Job(JobEvent),
    Gauge(ServiceGauge),
}

impl Event {
    /// Stable `type` discriminator used on the wire.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Round(_) => "round",
            Event::Job(_) => "job",
            Event::Gauge(_) => "gauge",
        }
    }

    /// Serialize to one flat JSON object (keys sorted by `util::json`).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("type", self.kind());
        match self {
            Event::Round(r) => {
                obj.set("t_idx", r.t_idx)
                    .set("y", r.y)
                    .set("round", r.round)
                    .set("objective", r.objective)
                    .set("train_loss", r.train_loss)
                    .set(
                        "eval_loss",
                        match r.eval_loss {
                            Some(v) => Json::Num(v),
                            None => Json::Null,
                        },
                    )
                    .set("round_wall_ms", r.round_wall_ms);
            }
            Event::Job(j) => {
                obj.set("t_idx", j.t_idx)
                    .set("y", j.y)
                    .set("phase", j.phase.name())
                    .set("attempt", j.attempt)
                    .set("rounds_trained", j.rounds_trained)
                    .set("detail", j.detail.as_str());
            }
            Event::Gauge(g) => {
                obj.set("queue_depth", g.queue_depth)
                    .set("requests_served", g.requests_served)
                    .set("batches_run", g.batches_run)
                    .set("max_coalesced", g.max_coalesced);
            }
        }
        obj
    }

    /// Serialize to one fixed-arity CSV row under [`CSV_HEADER`].
    pub fn to_csv_row(&self) -> String {
        let mut f: Vec<String> = vec![String::new(); 16];
        f[0] = self.kind().to_string();
        match self {
            Event::Round(r) => {
                f[1] = r.t_idx.to_string();
                f[2] = r.y.to_string();
                f[3] = r.round.to_string();
                f[6] = r.objective.to_string();
                f[7] = r.train_loss.to_string();
                if let Some(v) = r.eval_loss {
                    f[8] = v.to_string();
                }
                f[9] = r.round_wall_ms.to_string();
            }
            Event::Job(j) => {
                f[1] = j.t_idx.to_string();
                f[2] = j.y.to_string();
                f[4] = j.attempt.to_string();
                f[5] = j.phase.name().to_string();
                f[10] = j.rounds_trained.to_string();
                f[15] = csv_field(&j.detail);
            }
            Event::Gauge(g) => {
                f[11] = g.queue_depth.to_string();
                f[12] = g.requests_served.to_string();
                f[13] = g.batches_run.to_string();
                f[14] = g.max_coalesced.to_string();
            }
        }
        f.join(",")
    }
}

/// RFC-4180 quoting: fields containing a comma, quote, or newline are wrapped
/// in double quotes with internal quotes doubled.
fn csv_field(s: &str) -> String {
    if s.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// The bounded, off-hot-path event sink.
///
/// Emitters share it as `&EventSink` (a `SyncSender` is `Sync`, so one sink
/// serves every job-slot thread without cloning); the single writer thread
/// owns the output. A full queue drops the event and bumps the counter —
/// `emit` never waits on I/O.
pub struct EventSink {
    tx: Option<mpsc::SyncSender<Event>>,
    dropped: Arc<AtomicU64>,
    writer: Option<JoinHandle<()>>,
}

impl EventSink {
    /// Open a file-backed sink, creating parent directories. The format
    /// follows the extension ([`EventFormat::for_path`]).
    pub fn to_path(path: &Path) -> io::Result<EventSink> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let format = EventFormat::for_path(path);
        let file = std::fs::File::create(path)?;
        Ok(EventSink::to_writer(
            Box::new(BufWriter::new(file)),
            format,
            DEFAULT_QUEUE_EVENTS,
        ))
    }

    /// Build a sink over an arbitrary writer with an explicit queue capacity.
    /// `out` receives one `write` per line (wrap it in a `BufWriter` if that
    /// matters); tests use this to observe and to throttle the writer.
    pub fn to_writer(
        out: Box<dyn Write + Send>,
        format: EventFormat,
        queue_capacity: usize,
    ) -> EventSink {
        let (tx, rx) = mpsc::sync_channel::<Event>(queue_capacity.max(1));
        let dropped = Arc::new(AtomicU64::new(0));
        let counter = Arc::clone(&dropped);
        let writer = std::thread::Builder::new()
            .name("event-sink".into())
            .spawn(move || drain(rx, out, format, &counter))
            .expect("spawn event-sink writer");
        EventSink { tx: Some(tx), dropped, writer: Some(writer) }
    }

    /// Enqueue one event. Never blocks: a full queue (or a sink already shut
    /// down) drops the event and increments the dropped counter.
    pub fn emit(&self, event: Event) {
        let Some(tx) = &self.tx else { return };
        if tx.try_send(event).is_err() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Events lost to a full queue or a dead output so far. A completed run
    /// with 0 here has a gap-free log.
    pub fn dropped_events(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

impl Drop for EventSink {
    fn drop(&mut self) {
        // Closing the sender lets the writer drain the queue and exit; the
        // join guarantees the file is flushed and complete before the owner
        // (e.g. `run_training`) returns.
        self.tx.take();
        if let Some(handle) = self.writer.take() {
            let _ = handle.join();
        }
    }
}

/// Writer-thread loop: format and write each event, flushing periodically.
/// A dead output (closed pipe, full disk) flips the sink into drain-and-count
/// mode — emitters keep their non-blocking guarantee either way.
fn drain(
    rx: mpsc::Receiver<Event>,
    out: Box<dyn Write + Send>,
    format: EventFormat,
    dropped: &AtomicU64,
) {
    let mut w = out;
    let mut alive = true;
    if format == EventFormat::Csv {
        alive = writeln!(w, "{CSV_HEADER}").is_ok();
    }
    let mut since_flush = 0usize;
    for event in rx {
        if !alive {
            dropped.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        let line = match format {
            EventFormat::Jsonl => event.to_json().to_string(),
            EventFormat::Csv => event.to_csv_row(),
        };
        if writeln!(w, "{line}").is_err() {
            alive = false;
            dropped.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        since_flush += 1;
        if since_flush >= FLUSH_EVERY {
            let _ = w.flush();
            since_flush = 0;
        }
    }
    let _ = w.flush();
}

/// Per-job handle the boosting loop logs rounds through: pins the `(t, y)`
/// identity once so the hot loop passes only per-round values.
pub struct RoundLog<'a> {
    sink: &'a EventSink,
    t_idx: usize,
    y: usize,
}

impl<'a> RoundLog<'a> {
    pub fn new(sink: &'a EventSink, t_idx: usize, y: usize) -> RoundLog<'a> {
        RoundLog { sink, t_idx, y }
    }

    /// Emit one [`TrainRoundEvent`] (a single bounded-channel `try_send`).
    pub fn round(
        &self,
        round: usize,
        objective: &'static str,
        train_loss: f64,
        eval_loss: Option<f64>,
        round_wall_ms: f64,
    ) {
        self.sink.emit(Event::Round(TrainRoundEvent {
            t_idx: self.t_idx,
            y: self.y,
            round,
            objective,
            train_loss,
            eval_loss,
            round_wall_ms,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    /// Test writer backed by a shared buffer the test can read after the
    /// sink (and with it the writer thread) has been dropped.
    #[derive(Clone)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);

    impl SharedBuf {
        fn new() -> SharedBuf {
            SharedBuf(Arc::new(Mutex::new(Vec::new())))
        }

        fn text(&self) -> String {
            String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
        }
    }

    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    /// Sleeps on every write call: with a tiny queue this forces overflow
    /// while the emitter must stay non-blocking.
    struct SlowWriter {
        inner: SharedBuf,
        delay: Duration,
    }

    impl Write for SlowWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            std::thread::sleep(self.delay);
            self.inner.write(buf)
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    fn round_event(t_idx: usize, round: usize) -> TrainRoundEvent {
        TrainRoundEvent {
            t_idx,
            y: 0,
            round,
            objective: "sqerr",
            train_loss: 0.5,
            eval_loss: Some(0.25),
            round_wall_ms: 1.5,
        }
    }

    #[test]
    fn format_follows_the_path_extension() {
        assert_eq!(EventFormat::for_path(Path::new("a/b/events.csv")), EventFormat::Csv);
        assert_eq!(EventFormat::for_path(Path::new("events.jsonl")), EventFormat::Jsonl);
        assert_eq!(EventFormat::for_path(Path::new("events")), EventFormat::Jsonl);
    }

    #[test]
    fn jsonl_events_roundtrip_through_the_parser() {
        let parsed = Json::parse(&Event::Round(round_event(3, 7)).to_json().to_string()).unwrap();
        assert_eq!(parsed.get("type").unwrap().as_str(), Some("round"));
        assert_eq!(parsed.get("t_idx").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("round").unwrap().as_usize(), Some(7));
        assert_eq!(parsed.get("objective").unwrap().as_str(), Some("sqerr"));
        assert_eq!(parsed.get("eval_loss").unwrap().as_f64(), Some(0.25));

        // A missing eval loss serializes as null, not a number.
        let no_eval = Event::Round(TrainRoundEvent { eval_loss: None, ..round_event(0, 0) });
        let parsed = Json::parse(&no_eval.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("eval_loss"), Some(&Json::Null));

        let job = Event::Job(JobEvent {
            t_idx: 1,
            y: 2,
            phase: JobPhase::Retried,
            attempt: 0,
            rounds_trained: 0,
            detail: "panic: \"quoted\", with comma".into(),
        });
        let parsed = Json::parse(&job.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("phase").unwrap().as_str(), Some("retried"));
        assert_eq!(
            parsed.get("detail").unwrap().as_str(),
            Some("panic: \"quoted\", with comma")
        );

        let gauge = Event::Gauge(ServiceGauge {
            queue_depth: 4,
            requests_served: 9,
            batches_run: 2,
            max_coalesced: 5,
        });
        let parsed = Json::parse(&gauge.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("type").unwrap().as_str(), Some("gauge"));
        assert_eq!(parsed.get("queue_depth").unwrap().as_usize(), Some(4));
        assert_eq!(parsed.get("max_coalesced").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn csv_rows_are_fixed_arity_with_quoted_details() {
        let cols = CSV_HEADER.split(',').count();
        let r = Event::Round(round_event(1, 2)).to_csv_row();
        assert_eq!(r.split(',').count(), cols, "{r}");
        assert!(r.starts_with("round,1,0,2,"), "{r}");

        let g = Event::Gauge(ServiceGauge::default()).to_csv_row();
        assert_eq!(g.split(',').count(), cols, "{g}");

        // Commas and quotes in the failure detail get RFC-4180 quoting.
        let j = Event::Job(JobEvent {
            t_idx: 0,
            y: 1,
            phase: JobPhase::Failed,
            attempt: 2,
            rounds_trained: 0,
            detail: "a, \"b\"".into(),
        })
        .to_csv_row();
        assert!(j.ends_with("\"a, \"\"b\"\"\""), "{j}");
    }

    #[test]
    fn sink_preserves_emit_order_and_drops_nothing_under_capacity() {
        let buf = SharedBuf::new();
        let sink =
            EventSink::to_writer(Box::new(buf.clone()), EventFormat::Jsonl, DEFAULT_QUEUE_EVENTS);
        for i in 0..100 {
            sink.emit(Event::Round(round_event(0, i)));
        }
        assert_eq!(sink.dropped_events(), 0);
        drop(sink); // joins the writer: everything below is flushed
        let text = buf.text();
        let rounds: Vec<usize> = text
            .lines()
            .map(|l| Json::parse(l).unwrap().get("round").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(rounds, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn csv_sink_writes_the_header_first() {
        let buf = SharedBuf::new();
        let sink = EventSink::to_writer(Box::new(buf.clone()), EventFormat::Csv, 16);
        sink.emit(Event::Round(round_event(0, 0)));
        drop(sink);
        let text = buf.text();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some(CSV_HEADER));
        assert!(lines.next().unwrap().starts_with("round,"), "{text}");
    }

    #[test]
    fn overflow_drops_events_but_never_blocks_the_emitter() {
        let buf = SharedBuf::new();
        let slow = SlowWriter { inner: buf.clone(), delay: Duration::from_millis(25) };
        let sink = EventSink::to_writer(Box::new(slow), EventFormat::Jsonl, 2);
        let n = 40u64;
        let t0 = Instant::now();
        for i in 0..n {
            sink.emit(Event::Round(round_event(0, i as usize)));
        }
        let emit_elapsed = t0.elapsed();
        // Serial drain needs >= 25 ms x 40 = 1 s; the emitter must come
        // nowhere near that — try_send never waits for the writer.
        assert!(emit_elapsed < Duration::from_millis(500), "emitter stalled: {emit_elapsed:?}");
        let dropped = sink.dropped_events();
        assert!(dropped > 0, "a 2-slot queue behind a slow writer must shed load");
        drop(sink);
        let written = buf.text().lines().count() as u64;
        assert_eq!(written + dropped, n, "every event is either written or counted dropped");
    }

    #[test]
    fn to_path_creates_parents_and_writes_jsonl() {
        let dir = std::env::temp_dir().join("caloforest_events_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested").join("run.jsonl");
        let sink = EventSink::to_path(&path).unwrap();
        sink.emit(Event::Round(round_event(2, 0)));
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1);
        let v = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("t_idx").unwrap().as_usize(), Some(2));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
