//! Deterministic fault injection for the fault-tolerance test surface.
//!
//! Production code calls the site hooks ([`job_fault`], [`io_fault`]) at
//! named failure points; with no plan installed the hooks return `None` and
//! cost one mutex probe. A plan is installed either from the
//! `CALOFOREST_FAULT_PLAN` environment variable (read once, lazily — the CI
//! fault leg) or scoped per-test via [`scoped`], which serializes every
//! faulted test behind one lock so concurrent tests never see each other's
//! plans.
//!
//! Plan grammar — comma-separated entries, each `site:key:action`:
//!
//! * `site` — `job` (a whole training job attempt: panic before training)
//!   or `io` (a model-file write: fail inside `serialize::save`).
//! * `key` — `*` (any hit), a decimal job index into the run's job list
//!   (`job` sites only), or a slot stem like `t0002_y001` (both sites).
//! * `action` — `panic` (every hit), `io` (an I/O error every hit),
//!   `once` (the site's natural kind, first hit only: `job` → panic,
//!   `io` → I/O error), or `panic@N` / `io@N` (first `N` hits only).
//!
//! Example: `CALOFOREST_FAULT_PLAN="job:3:panic,io:t0002_y001:once"` — job
//! 3 panics on every attempt (exhausting retries ⇒ a failed slot) and the
//! first write of slot `t0002_y001` fails (the retry then succeeds).
//!
//! Determinism: each entry carries its own hit counter, so a plan replays
//! identically for a fixed schedule. Keyed entries (`job:3`, `io:t0002_*`)
//! fire on the same job regardless of which worker claims it; `*` entries
//! with a bounded count fire on whichever hit arrives first — use keys when
//! asserting on specific slots.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// What an injected fault does at its site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Unwind the site with a panic (a crashing job).
    Panic,
    /// Return an `io::Error` from the site (a full disk, a failed write).
    Io,
}

/// Injection sites the plan can address.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// One `(t, y)` training-job attempt in the coordinator.
    Job,
    /// One model-file write in `serialize::save`.
    Io,
}

#[derive(Debug)]
enum SiteKey {
    Any,
    JobIndex(usize),
    Name(String),
}

#[derive(Debug)]
struct FaultEntry {
    site: Site,
    key: SiteKey,
    kind: FaultKind,
    /// Fire on the first `times` matching hits (`u32::MAX` = every hit).
    times: u32,
    hits: AtomicU32,
}

/// A parsed fault plan: an ordered set of independent fault entries.
#[derive(Debug, Default)]
pub struct FaultPlan {
    entries: Vec<FaultEntry>,
}

impl FaultPlan {
    /// Parse the plan grammar (see the module docs). Errors name the
    /// offending entry.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut entries = Vec::new();
        for raw in spec.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            let parts: Vec<&str> = entry.split(':').collect();
            let &[site, key, action] = parts.as_slice() else {
                return Err(format!("fault entry '{entry}' is not site:key:action"));
            };
            let site = match site {
                "job" => Site::Job,
                "io" => Site::Io,
                other => return Err(format!("unknown fault site '{other}' in '{entry}'")),
            };
            let key = if key == "*" {
                SiteKey::Any
            } else if let Ok(idx) = key.parse::<usize>() {
                SiteKey::JobIndex(idx)
            } else {
                SiteKey::Name(key.to_string())
            };
            let (kind, times) = parse_action(action, site)
                .ok_or_else(|| format!("unknown fault action '{action}' in '{entry}'"))?;
            entries.push(FaultEntry { site, key, kind, times, hits: AtomicU32::new(0) });
        }
        Ok(FaultPlan { entries })
    }

    /// Record a hit at `site` and return the fault to inject, if any.
    fn fire(&self, site: Site, index: Option<usize>, name: &str) -> Option<FaultKind> {
        for e in &self.entries {
            if e.site != site {
                continue;
            }
            let matched = match &e.key {
                SiteKey::Any => true,
                SiteKey::JobIndex(i) => index == Some(*i),
                SiteKey::Name(n) => n == name,
            };
            if !matched {
                continue;
            }
            let hit = e.hits.fetch_add(1, Ordering::Relaxed);
            if hit < e.times {
                return Some(e.kind);
            }
        }
        None
    }
}

fn parse_action(action: &str, site: Site) -> Option<(FaultKind, u32)> {
    if action == "once" {
        let natural = match site {
            Site::Job => FaultKind::Panic,
            Site::Io => FaultKind::Io,
        };
        return Some((natural, 1));
    }
    let (kind_str, times) = match action.split_once('@') {
        Some((k, n)) => (k, n.parse::<u32>().ok().filter(|&n| n > 0)?),
        None => (action, u32::MAX),
    };
    let kind = match kind_str {
        "panic" => FaultKind::Panic,
        "io" => FaultKind::Io,
        _ => return None,
    };
    Some((kind, times))
}

/// The active plan: `None` = no faults. Initialized once from the
/// environment; [`scoped`] swaps it for a test's lifetime.
fn active() -> &'static Mutex<Option<Arc<FaultPlan>>> {
    static ACTIVE: OnceLock<Mutex<Option<Arc<FaultPlan>>>> = OnceLock::new();
    ACTIVE.get_or_init(|| Mutex::new(plan_from_env()))
}

fn plan_from_env() -> Option<Arc<FaultPlan>> {
    let spec = std::env::var("CALOFOREST_FAULT_PLAN").ok()?;
    if spec.trim().is_empty() {
        return None;
    }
    let plan = FaultPlan::parse(&spec)
        .unwrap_or_else(|e| panic!("invalid CALOFOREST_FAULT_PLAN: {e}"));
    Some(Arc::new(plan))
}

/// Serializes scoped installs: tests that inject faults run one at a time,
/// so a plan never leaks into an unrelated concurrent test.
static SCOPE_LOCK: Mutex<()> = Mutex::new(());

/// Guard for a scoped plan install; dropping it restores the previous plan
/// (usually the environment-derived one) and releases the test serializer.
pub struct ScopedPlan {
    _serial: MutexGuard<'static, ()>,
    prev: Option<Arc<FaultPlan>>,
}

/// Install `spec` as the active plan until the guard drops. An empty spec
/// installs a no-fault plan (shadowing any `CALOFOREST_FAULT_PLAN`), which
/// is how fault tests run their clean reference passes.
pub fn scoped(spec: &str) -> ScopedPlan {
    // A previous test panicking mid-scope poisons the lock but leaves the
    // plan restoration to its guard's Drop; the lock itself is still fine.
    let serial = SCOPE_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let plan = FaultPlan::parse(spec).expect("invalid scoped fault plan");
    let plan = (!plan.entries.is_empty()).then(|| Arc::new(plan));
    let prev = std::mem::replace(&mut *active().lock().unwrap(), plan);
    ScopedPlan { _serial: serial, prev }
}

/// Re-install the environment plan with fresh hit counters, under the same
/// test serializer as [`scoped`]. Returns `None` (taking no lock) when
/// `CALOFOREST_FAULT_PLAN` is unset or empty — the CI fault leg's smoke
/// test no-ops cleanly elsewhere.
pub fn scoped_from_env() -> Option<ScopedPlan> {
    let spec = std::env::var("CALOFOREST_FAULT_PLAN").ok()?;
    if spec.trim().is_empty() {
        return None;
    }
    Some(scoped(&spec))
}

impl Drop for ScopedPlan {
    fn drop(&mut self) {
        *active().lock().unwrap() = self.prev.take();
    }
}

/// Site hook: one training-job attempt. `job_idx` indexes the run's job
/// list; `slot` is the slot stem (`tXXXX_yYYY`), stable across resumes.
pub fn job_fault(job_idx: usize, slot: &str) -> Option<FaultKind> {
    fire(Site::Job, Some(job_idx), slot)
}

/// Site hook: one model-file write. `name` is the destination file stem.
pub fn io_fault(name: &str) -> Option<FaultKind> {
    fire(Site::Io, None, name)
}

fn fire(site: Site, index: Option<usize>, name: &str) -> Option<FaultKind> {
    let guard = active().lock().unwrap();
    guard.as_ref().and_then(|p| p.fire(site, index, name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_grammar() {
        let plan = FaultPlan::parse("job:3:panic,io:t0002_y001:once").unwrap();
        assert_eq!(plan.entries.len(), 2);
        // Job 3 panics on every attempt.
        assert_eq!(plan.fire(Site::Job, Some(3), "t0001_y001"), Some(FaultKind::Panic));
        assert_eq!(plan.fire(Site::Job, Some(3), "t0001_y001"), Some(FaultKind::Panic));
        assert_eq!(plan.fire(Site::Job, Some(2), "t0001_y000"), None);
        // The named write fails exactly once.
        assert_eq!(plan.fire(Site::Io, None, "t0002_y001"), Some(FaultKind::Io));
        assert_eq!(plan.fire(Site::Io, None, "t0002_y001"), None);
        assert_eq!(plan.fire(Site::Io, None, "t0000_y000"), None);
    }

    #[test]
    fn bounded_counts_wildcards_and_name_keyed_jobs() {
        let plan = FaultPlan::parse("job:*:io@2,job:t0001_y000:panic@1").unwrap();
        // The wildcard I/O entry fires twice, then drains.
        assert_eq!(plan.fire(Site::Job, Some(0), "t0000_y000"), Some(FaultKind::Io));
        assert_eq!(plan.fire(Site::Job, Some(1), "t0000_y001"), Some(FaultKind::Io));
        // Third hit falls through to the name-keyed entry.
        assert_eq!(plan.fire(Site::Job, Some(2), "t0001_y000"), Some(FaultKind::Panic));
        assert_eq!(plan.fire(Site::Job, Some(2), "t0001_y000"), None);
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("job:3").is_err());
        assert!(FaultPlan::parse("disk:3:panic").is_err());
        assert!(FaultPlan::parse("job:3:explode").is_err());
        assert!(FaultPlan::parse("job:3:panic@0").is_err());
        assert!(FaultPlan::parse("job:3:panic@x").is_err());
        // Empty / whitespace specs are valid no-fault plans.
        assert!(FaultPlan::parse("").unwrap().entries.is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().entries.is_empty());
    }

    #[test]
    fn scoped_install_overrides_and_restores() {
        {
            let _guard = scoped("io:model:once");
            assert_eq!(io_fault("model"), Some(FaultKind::Io));
            assert_eq!(io_fault("model"), None, "once-entry drained");
        }
        // Outside the scope the hook is inert again (no env plan in unit
        // tests; under the CI fault leg the env plan is restored instead,
        // which never addresses the stem used here).
        assert_eq!(io_fault("no_such_site_stem"), None);
    }
}
