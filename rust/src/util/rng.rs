//! Deterministic, splittable pseudo-random number generation.
//!
//! `xoshiro256**` core seeded through SplitMix64, following the reference
//! implementations by Blackman & Vigna. Every stochastic component of the
//! library takes an explicit [`Rng`] (or a `u64` seed), which makes all
//! experiments reproducible bit-for-bit on a given host.

/// SplitMix64 step: used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller transform.
    gauss_cache: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Derive an independent child stream; `tag` distinguishes siblings.
    ///
    /// Used to hand each training job in the `(t, y)` grid its own stream so
    /// results do not depend on scheduling order.
    pub fn split(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_cache: None }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's method (unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        // Rejection-free Box–Muller; u1 in (0,1].
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_cache = Some(r * s);
        r * c
    }

    /// Standard normal `f32`.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Advance the generator past `n` normal draws — the state afterwards is
    /// identical to drawing and discarding them (including the Box–Muller
    /// pair cache). Lets counter-based streams start mid-chunk.
    ///
    /// Fast path: a full Box–Muller pair consumes exactly two uniforms, so
    /// whole pairs are skipped with raw draws (no `ln`/`sqrt`/`sin_cos`);
    /// only an odd final draw pays the real transform, because it must
    /// leave its sibling in the pair cache exactly as [`normal`](Self::normal)
    /// would.
    pub fn skip_normals(&mut self, mut n: usize) {
        if n == 0 {
            return;
        }
        if self.gauss_cache.take().is_some() {
            n -= 1;
        }
        for _ in 0..n / 2 {
            let _ = self.uniform();
            let _ = self.uniform();
        }
        if n % 2 == 1 {
            let _ = self.normal();
        }
    }

    /// Fill a slice with uniforms in `[lo, hi)`.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.uniform_f32();
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Draw `n` samples from a multinomial over `probs` (returns counts).
    pub fn multinomial(&mut self, n: usize, probs: &[f64]) -> Vec<usize> {
        let mut counts = vec![0usize; probs.len()];
        for _ in 0..n {
            counts[self.categorical(probs)] += 1;
        }
        counts
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang (k ≥ 0, θ > 0).
    ///
    /// Used by the calorimeter shower simulator for longitudinal profiles.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^{1/k}
            let u = self.uniform().max(1e-300);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * theta;
            }
        }
    }
}

/// Counter-based stream of standard normals addressed by
/// `(replica, row, column)` — the virtual K-duplication noise definition.
///
/// Values are realized per **fixed row chunk** ([`Self::CHUNK_ROWS`] rows in
/// the *original, undup'd* row coordinates): chunk `c` of replica `r` is the
/// independent child stream `Rng::new(seed).split(r << 32 | c)`, whose
/// normals fill the chunk's rows in row-major order. Chunk boundaries are a
/// pure function of the global row index — never of the requested range, the
/// worker count, or a class slice — so any sub-range read reproduces exactly
/// the values the full matrix would contain (*slice-invariance*), and
/// chunk-parallel generation is bit-identical under any scheduling
/// (*width-invariance*). The stream itself is `O(1)` state: two words
/// standing in for what a materialized `[n·K × p]` noise matrix used to be.
#[derive(Clone, Copy, Debug)]
pub struct NormalStream {
    seed: u64,
    cols: usize,
}

impl NormalStream {
    /// Rows per realization chunk. Small enough that a mid-chunk read skips
    /// at most `CHUNK_ROWS − 1` rows of draws, large enough that one chunk
    /// amortizes its child-`Rng` construction over thousands of values.
    pub const CHUNK_ROWS: usize = 256;

    pub fn new(seed: u64, cols: usize) -> NormalStream {
        NormalStream { seed, cols }
    }

    /// Values per row.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The defining seed (also used to derive the flawed-iterator rolling
    /// generator in `forest::dataiter`).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Child generator owning `(replica, chunk)`.
    fn chunk_rng(&self, replica: usize, chunk: usize) -> Rng {
        debug_assert!(
            (replica as u64) < (1 << 32) && (chunk as u64) < (1 << 32),
            "replica/chunk out of keyable range"
        );
        Rng::new(self.seed).split(((replica as u64) << 32) | chunk as u64)
    }

    /// Fill `out` (`rows × cols` values, row-major) with the noise of rows
    /// `[row0, row0 + rows)` of `replica` — bit-identical to slicing those
    /// rows out of a full-matrix fill.
    pub fn fill(&self, replica: usize, row0: usize, rows: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), rows * self.cols, "fill buffer/shape mismatch");
        let ch = Self::CHUNK_ROWS;
        let mut row = row0;
        let mut off = 0usize;
        while row < row0 + rows {
            let chunk = row / ch;
            let take = (row0 + rows).min((chunk + 1) * ch) - row;
            let mut rng = self.chunk_rng(replica, chunk);
            rng.skip_normals((row - chunk * ch) * self.cols);
            rng.fill_normal(&mut out[off..off + take * self.cols]);
            row += take;
            off += take * self.cols;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn split_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.split(0);
        let mut b = root.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(42);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            sum2 += u * u;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
            s3 += z * z * z;
        }
        let m = s / n as f64;
        assert!(m.abs() < 0.02);
        assert!((s2 / n as f64 - 1.0).abs() < 0.03);
        assert!((s3 / n as f64).abs() < 0.05); // symmetry
    }

    #[test]
    fn below_is_unbiased_smoke() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn multinomial_counts_sum() {
        let mut r = Rng::new(9);
        let counts = r.multinomial(1000, &[0.2, 0.3, 0.5]);
        assert_eq!(counts.iter().sum::<usize>(), 1000);
        assert!(counts[2] > counts[0]);
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(11);
        let (k, theta) = (3.0, 2.0);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.gamma(k, theta)).sum::<f64>() / n as f64;
        assert!((mean - k * theta).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn skip_normals_equals_draw_and_discard() {
        // Even and odd counts (the pair-cache state differs), from both a
        // fresh generator and one whose pair cache is already primed.
        for skip in [0usize, 1, 2, 7, 8, 513] {
            for prime in [0usize, 1] {
                let mut a = Rng::new(21);
                let mut b = Rng::new(21);
                for _ in 0..prime {
                    let _ = a.normal();
                    let _ = b.normal();
                }
                for _ in 0..skip {
                    let _ = a.normal();
                }
                b.skip_normals(skip);
                for _ in 0..4 {
                    assert_eq!(
                        a.normal().to_bits(),
                        b.normal().to_bits(),
                        "skip={skip} prime={prime}"
                    );
                }
            }
        }
    }

    #[test]
    fn stream_fill_is_deterministic_and_replica_keyed() {
        let s = NormalStream::new(33, 3);
        let mut a = vec![0.0f32; 10 * 3];
        let mut b = vec![0.0f32; 10 * 3];
        s.fill(0, 5, 10, &mut a);
        s.fill(0, 5, 10, &mut b);
        assert_eq!(a, b);
        s.fill(1, 5, 10, &mut b);
        assert_ne!(a, b, "replicas must be independent streams");
        NormalStream::new(34, 3).fill(0, 5, 10, &mut b);
        assert_ne!(a, b, "seeds must be independent streams");
    }

    #[test]
    fn stream_subrange_fill_matches_full_fill_across_chunks() {
        // 600 rows spans three 256-row chunks; sub-ranges starting mid-chunk
        // and crossing chunk boundaries must reproduce the full fill.
        let p = 2;
        let s = NormalStream::new(7, p);
        let n = 600;
        let mut full = vec![0.0f32; n * p];
        s.fill(3, 0, n, &mut full);
        for (r0, rows) in [(0, 600), (250, 280), (255, 2), (256, 256), (599, 1)] {
            let mut sub = vec![0.0f32; rows * p];
            s.fill(3, r0, rows, &mut sub);
            let want = &full[r0 * p..(r0 + rows) * p];
            assert_eq!(
                sub.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "sub-fill [{r0}, {}) diverges",
                r0 + rows
            );
        }
    }

    #[test]
    fn stream_values_are_standard_normal() {
        let s = NormalStream::new(55, 4);
        let n = 50_000;
        let mut v = vec![0.0f32; n * 4];
        s.fill(0, 0, n, &mut v);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let var: f64 =
            v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }
}
