//! Minimal JSON value, parser, and writer.
//!
//! Used for the config system, experiment result files, and the model-store
//! metadata. Supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null) plus pretty-printing. Not intended to be
//! the fastest parser alive — configs and result files are small.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — programmer error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with two-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (k, item) in items.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (k, (key, value)) in map.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    value.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of unescaped bytes (valid UTF-8 by input contract).
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8 in string")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("tab\t quote\" backslash\\ nl\n".into());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("n", 3usize).set("name", "calo").set("ok", true);
        assert_eq!(o.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(o.get("name").unwrap().as_str(), Some("calo"));
        assert_eq!(o.get("ok").unwrap().as_bool(), Some(true));
    }
}
