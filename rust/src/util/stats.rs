//! Descriptive statistics, quantiles, histograms, and sorting helpers used
//! throughout the evaluation and benchmarking code.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 if n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    std(xs) / (xs.len() as f64).sqrt()
}

/// Linear-interpolation quantile of unsorted data, `q` in `[0, 1]`.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Quantile of already-sorted data.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Indices that would sort `xs` ascending (stable).
pub fn argsort_f32(xs: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// Indices that would sort `xs` ascending (stable).
pub fn argsort_u32(xs: &[u32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by_key(|&a| xs[a]);
    idx
}

/// Fixed-width histogram over `[lo, hi]` with `bins` bins; returns counts.
/// Values outside the range are clamped into the edge bins (matching how the
/// CaloChallenge evaluation treats over/underflow).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let b = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        counts[b] += 1;
    }
    counts
}

/// Normalize counts to fractions summing to 1 (uniform if empty).
pub fn normalize(counts: &[usize]) -> Vec<f64> {
    let total: usize = counts.iter().sum();
    if total == 0 {
        return vec![1.0 / counts.len() as f64; counts.len()];
    }
    counts.iter().map(|&c| c as f64 / total as f64).collect()
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..xs.len() {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_quantile() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert!((std(&xs) - (2.5f64).sqrt()).abs() < 1e-12);
        assert!((quantile(&xs, 0.5) - 3.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 5.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps_overflow() {
        let h = histogram(&[-10.0, 0.1, 0.9, 10.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
        let p = normalize(&h);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn argsort_stable() {
        let xs = [3.0f32, 1.0, 2.0, 1.0];
        assert_eq!(argsort_f32(&xs), vec![1, 3, 2, 0]);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }
}
