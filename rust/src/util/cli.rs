//! Declarative command-line argument parsing for the launcher.
//!
//! Supports `--key value`, `--key=value`, boolean flags, defaults, and
//! auto-generated `--help`. Subcommands are handled by the caller peeling
//! the first positional argument.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument parser.
#[derive(Clone, Debug)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare an option with a default value.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required option (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean flag (defaults to false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let d = match (&o.default, o.is_flag) {
                (_, true) => " (flag)".to_string(),
                (Some(d), _) => format!(" (default: {d})"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }

    /// Parse a token stream. Returns `Err` with a usage string on failure or
    /// `--help`.
    pub fn parse(mut self, tokens: &[String]) -> Result<Args, String> {
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?
                    .clone();
                let value = if let Some(v) = inline_val {
                    v
                } else if opt.is_flag {
                    "true".to_string()
                } else {
                    i += 1;
                    tokens
                        .get(i)
                        .cloned()
                        .ok_or_else(|| format!("--{key} needs a value"))?
                };
                self.values.insert(key, value);
            } else {
                self.positionals.push(tok.clone());
            }
            i += 1;
        }
        // Check required options.
        for o in &self.opts {
            if o.default.is_none() && !self.values.contains_key(&o.name) {
                return Err(format!("missing required --{}\n\n{}", o.name, self.usage()));
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_f32(&self, name: &str) -> f32 {
        self.get_f64(name) as f32
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name).as_str(), "true" | "1" | "yes")
    }

    /// Comma-separated list of integers, e.g. `--ns 100,300,1000`.
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad int '{s}'")))
            .collect()
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("t", "test")
            .opt("n", "100", "rows")
            .flag("fast", "go fast")
            .parse(&toks(&["--n", "250", "--fast"]))
            .unwrap();
        assert_eq!(a.get_usize("n"), 250);
        assert!(a.get_bool("fast"));
    }

    #[test]
    fn equals_syntax_and_lists() {
        let a = Args::new("t", "test")
            .opt("ns", "1,2", "list")
            .parse(&toks(&["--ns=10,20,30"]))
            .unwrap();
        assert_eq!(a.get_usize_list("ns"), vec![10, 20, 30]);
    }

    #[test]
    fn required_missing_errors() {
        let r = Args::new("t", "test").req("data", "path").parse(&toks(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_errors() {
        let r = Args::new("t", "test").parse(&toks(&["--bogus", "1"]));
        assert!(r.is_err());
    }

    #[test]
    fn positionals_pass_through() {
        let a = Args::new("t", "test")
            .opt("n", "1", "")
            .parse(&toks(&["train", "--n", "2"]))
            .unwrap();
        assert_eq!(a.positionals(), &["train".to_string()]);
    }
}
