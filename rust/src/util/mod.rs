//! Foundation utilities.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (`rand`, `serde_json`, `clap`, `rayon`, `criterion`, `proptest`) are not
//! available. This module provides small, well-tested replacements that the
//! rest of the crate builds on:
//!
//! * [`rng`] — counter-based splittable PRNG (SplitMix64 seeding a
//!   xoshiro256**) with normal/multinomial sampling.
//! * [`json`] — a JSON value type with parser and writer (configs, results).
//! * [`cli`] — declarative command-line parsing for the launcher.
//! * [`stats`] — descriptive statistics, quantiles, histograms, argsort.
//! * [`bench`] — a minimal criterion-style measurement harness used by all
//!   `cargo bench` targets.
//! * [`prop`] — a minimal property-based testing harness (randomized
//!   generators + counterexample reporting) used by the test suite.
//! * [`faultplan`] — deterministic fault injection (env-keyed panic/I/O
//!   faults at named sites) driving the fault-tolerance test surface.
//! * [`events`] — bounded-queue, off-hot-path training event sink (per-round
//!   and per-job telemetry to CSV/JSONL, drop-on-full, one writer thread).

pub mod rng;
pub mod json;
pub mod cli;
pub mod stats;
pub mod bench;
pub mod prop;
pub mod faultplan;
pub mod events;

pub use rng::Rng;
pub use json::Json;
