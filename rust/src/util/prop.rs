//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a [`Rng`]-driven generated input; the runner
//! executes it for `cases` random cases and, on failure, reports the failing
//! case index alongside the replay seed so the case can be re-run
//! deterministically. [`forall_shrink`] adds a greedy shrink pass over any
//! [`Shrink`] input — vectors, matrix dimensions, whole matrices — so the
//! panic carries a minimal failing input, not just the original one.
//!
//! CI's elevated-count property leg multiplies every run's case count via
//! the `CALOFOREST_PROP_CASES` env var (see [`Config::effective_cases`]).

use super::rng::Rng;
use crate::gbt::{BinnedMatrix, Booster, TrainParams, TreeKind};
use crate::tensor::Matrix;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

impl Config {
    /// Case count actually run: `cases` times the `CALOFOREST_PROP_CASES`
    /// multiplier (≥ 1; unset or unparsable means 1). A multiplier — not an
    /// absolute override — so cheap and expensive properties keep their
    /// relative budgets when CI elevates the whole suite.
    pub fn effective_cases(&self) -> usize {
        let mult = std::env::var("CALOFOREST_PROP_CASES")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&m| m >= 1)
            .unwrap_or(1);
        self.cases * mult
    }
}

/// Run `property(rng, case_index)` for every case, panicking with the
/// failing case index and the replay seed on error.
///
/// The property returns `Result<(), String>`; `Err` carries a description of
/// the violated invariant.
pub fn forall<F>(name: &str, cfg: Config, mut property: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let cases = cfg.effective_cases();
    for case in 0..cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        if let Err(msg) = property(&mut rng, case) {
            panic!(
                "property '{name}' failed on case {case} of {cases} \
                 (replay: seed={:#x}, split={case}): {msg}",
                cfg.seed
            );
        }
    }
}

/// Cap on greedy shrink steps taken by [`forall_shrink`].
const MAX_SHRINK_STEPS: usize = 64;

/// [`forall`] with an explicit generator and a shrink pass: on failure, the
/// first [`Shrink`] candidate that still fails replaces the input, repeated
/// to a fixpoint (or [`MAX_SHRINK_STEPS`]); the panic reports the failing
/// case index, the replay seed, the shrink-step count, and the minimal
/// input. Properties must be deterministic in their input — randomness
/// belongs in `generate`, which receives the case's replayable [`Rng`].
pub fn forall_shrink<T, G, P>(name: &str, cfg: Config, generate: G, property: P)
where
    T: Shrink + std::fmt::Debug,
    G: Fn(&mut Rng, usize) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let cases = cfg.effective_cases();
    for case in 0..cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        let input = generate(&mut rng, case);
        let msg = match property(&input) {
            Ok(()) => continue,
            Err(m) => m,
        };
        let mut cur = input;
        let mut cur_msg = msg;
        let mut steps = 0usize;
        'descend: while steps < MAX_SHRINK_STEPS {
            for cand in cur.shrink() {
                if let Err(m) = property(&cand) {
                    cur = cand;
                    cur_msg = m;
                    steps += 1;
                    continue 'descend;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed on case {case} of {cases} \
             (replay: seed={:#x}, split={case}; shrunk {steps} steps): \
             {cur_msg}\n  minimal input: {cur:?}",
            cfg.seed
        );
    }
}

/// Worker widths the parity/property test sweeps use:
/// `CALOFOREST_TEST_WORKERS` (CI's per-width matrix legs) *replaces* the
/// default `{1, 2, 8}` sweep so each matrix leg is genuinely
/// width-specific; without it the full default sweep runs. Shared by the
/// `parallel_parity` and `property_suite` crates so the two can never
/// drift apart under the same CI variable.
pub fn worker_widths() -> Vec<usize> {
    if let Ok(raw) = std::env::var("CALOFOREST_TEST_WORKERS") {
        if let Ok(w) = raw.trim().parse::<usize>() {
            if w >= 1 {
                return vec![w];
            }
        }
    }
    vec![1, 2, 8]
}

/// Duplication factor K for the parity sweeps: `CALOFOREST_TEST_KDUP` (CI's
/// elevated-duplication matrix leg) overrides the caller's default so the
/// virtual-duplication code paths also run at a K where the old
/// materialized `x0`/`x1` pair would have dominated memory.
pub fn test_kdup(default: usize) -> usize {
    std::env::var("CALOFOREST_TEST_KDUP")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&k| k >= 1)
        .unwrap_or(default)
}

/// Inputs the [`forall_shrink`] runner can reduce toward a minimal failing
/// case. Candidates must be *strictly* simpler than `self` (fewer elements,
/// smaller dimensions, or non-zero data zeroed) — the runner caps total
/// steps, but same-size candidates would stall the descent at the cap.
pub trait Shrink: Sized {
    /// Simplification candidates, most aggressive first; empty when fully
    /// shrunk.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for Vec<f32> {
    /// Halve (either half may hold the culprit), then zero the data.
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.len() >= 2 {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[self.len() / 2..].to_vec());
        }
        if self.iter().any(|&v| v != 0.0) {
            out.push(vec![0.0; self.len()]);
        }
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        match *self {
            0 => Vec::new(),
            1 => vec![0],
            n => vec![n / 2, n - 1],
        }
    }
}

/// Matrix dimensions `(rows, cols)` — shrink either axis.
impl Shrink for (usize, usize) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1)).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0, b)));
        out
    }
}

impl Shrink for Matrix {
    /// Halve rows (keep the top), halve columns (keep the left), then zero
    /// the data — dimensions first, so the minimal case is *small*, not
    /// merely simple.
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.rows >= 2 {
            let r = self.rows / 2;
            out.push(Matrix::from_vec(r, self.cols, self.data[..r * self.cols].to_vec()));
        }
        if self.cols >= 2 {
            let c = self.cols / 2;
            let mut data = Vec::with_capacity(self.rows * c);
            for r in 0..self.rows {
                data.extend_from_slice(&self.row(r)[..c]);
            }
            out.push(Matrix::from_vec(self.rows, c, data));
        }
        if self.data.iter().any(|&v| v != 0.0) {
            out.push(Matrix::zeros(self.rows, self.cols));
        }
        out
    }
}

/// A randomized trained booster bundled with its training data and bin
/// codes — the shared generator for training-path parity properties.
#[derive(Debug)]
pub struct BoosterCase {
    pub x: Matrix,
    pub binned: BinnedMatrix,
    pub booster: Booster,
}

/// Generator helpers for common tabular shapes.
pub struct Gen;

impl Gen {
    /// Random matrix dims: rows in [1, max_rows], cols in [1, max_cols].
    pub fn dims(rng: &mut Rng, max_rows: usize, max_cols: usize) -> (usize, usize) {
        (1 + rng.below(max_rows), 1 + rng.below(max_cols))
    }

    /// A vector of finite f32s in [-scale, scale], occasionally including
    /// exact zeros and repeated values (tree-split edge cases).
    pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            let r = rng.uniform();
            if r < 0.05 {
                v.push(0.0);
            } else if r < 0.10 && !v.is_empty() {
                let j = rng.below(v.len());
                v.push(v[j]); // duplicate an existing value
            } else {
                v.push(rng.range(-scale as f64, scale as f64) as f32);
            }
        }
        v
    }

    /// Class labels in [0, n_classes).
    pub fn labels(rng: &mut Rng, len: usize, n_classes: usize) -> Vec<u32> {
        (0..len).map(|_| rng.below(n_classes) as u32).collect()
    }

    /// A `rows × cols` matrix of [`Gen::vec_f32`]-style values with
    /// `nan_frac` of entries replaced by NaN (missing-value edge cases).
    pub fn matrix_with_nans(rng: &mut Rng, rows: usize, cols: usize, nan_frac: f64) -> Matrix {
        let mut x = Matrix::from_vec(rows, cols, Self::vec_f32(rng, rows * cols, 5.0));
        for v in x.data.iter_mut() {
            if rng.uniform() < nan_frac {
                *v = f32::NAN;
            }
        }
        x
    }

    /// A trained booster on randomized shapes and hyperparameters: random
    /// output dimension, bin budget, max depth (individual trees come out
    /// ragged — data runs dry at different depths), and ~8% missing
    /// entries. `case` alternates the [`TreeKind`] so both families appear
    /// deterministically across any run.
    pub fn booster_case(rng: &mut Rng, case: usize) -> BoosterCase {
        let n = 20 + rng.below(120);
        let p = 1 + rng.below(4);
        let m = 1 + rng.below(3);
        let x = Self::matrix_with_nans(rng, n, p, 0.08);
        let mut y = Matrix::zeros(n, m);
        for v in y.data.iter_mut() {
            *v = rng.normal_f32();
        }
        let kind = if case % 2 == 0 { TreeKind::Single } else { TreeKind::Multi };
        let params = TrainParams {
            n_trees: 1 + rng.below(5),
            max_depth: 1 + rng.below(6),
            kind,
            max_bins: 8 + rng.below(120),
            ..Default::default()
        };
        let binned = BinnedMatrix::fit_bin(&x.view(), params.max_bins);
        let booster = Booster::train_binned(&binned, &y.view(), params, None);
        BoosterCase { x, binned, booster }
    }
}

/// The f32 slice as raw bit patterns — the comparator every bit-identity
/// suite uses (`assert_eq!(bits_f32(&a), bits_f32(&b))` distinguishes
/// `-0.0` from `0.0` and NaN payloads, which `==` on floats cannot).
pub fn bits_f32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Assert two slices are elementwise close; returns Err description if not.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for i in 0..a.len() {
        let tol = atol + rtol * b[i].abs();
        if (a[i] - b[i]).abs() > tol || a[i].is_nan() != b[i].is_nan() {
            return Err(format!(
                "element {i}: {} vs {} (tol {tol}); context a[{}..{}]={:?}",
                a[i],
                b[i],
                i.saturating_sub(2),
                (i + 3).min(a.len()),
                &a[i.saturating_sub(2)..(i + 3).min(a.len())]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("uniform in range", Config::default(), |rng, _| {
            let u = rng.uniform();
            if (0.0..1.0).contains(&u) {
                Ok(())
            } else {
                Err(format!("u={u}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure() {
        forall("always fails", Config { cases: 2, seed: 1 }, |_, _| Err("nope".into()));
    }

    #[test]
    fn close_checks() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }

    #[test]
    fn gen_shapes() {
        let mut rng = Rng::new(2);
        let (r, c) = Gen::dims(&mut rng, 10, 5);
        assert!((1..=10).contains(&r) && (1..=5).contains(&c));
        let v = Gen::vec_f32(&mut rng, 100, 3.0);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|x| x.is_finite() && x.abs() <= 3.0));
        let y = Gen::labels(&mut rng, 50, 4);
        assert!(y.iter().all(|&l| l < 4));
    }

    #[test]
    fn gen_matrix_with_nans_hits_requested_fraction_roughly() {
        let mut rng = Rng::new(3);
        let x = Gen::matrix_with_nans(&mut rng, 100, 10, 0.2);
        let nans = x.data.iter().filter(|v| v.is_nan()).count();
        assert!((100..300).contains(&nans), "nan count {nans} far from 20%");
    }

    #[test]
    fn gen_booster_case_trains_both_kinds() {
        for case in 0..2usize {
            let mut rng = Rng::new(9).split(case as u64);
            let bc = Gen::booster_case(&mut rng, case);
            assert!(!bc.booster.trees.is_empty());
            assert_eq!(bc.binned.n, bc.x.rows);
            assert_eq!(bc.binned.p, bc.x.cols);
            let expect = if case % 2 == 0 { TreeKind::Single } else { TreeKind::Multi };
            assert_eq!(bc.booster.params.kind, expect);
        }
    }

    #[test]
    fn shrink_candidates_are_strictly_simpler() {
        // usize: every candidate strictly smaller.
        for n in [0usize, 1, 2, 17] {
            for c in n.shrink() {
                assert!(c < n, "usize shrink {c} !< {n}");
            }
        }
        // Vec<f32>: fewer elements, or same length with data newly zeroed.
        let v = vec![1.0f32, 0.0, -2.0, 3.5, 4.0];
        for c in v.shrink() {
            assert!(
                c.len() < v.len() || c.iter().all(|&x| x == 0.0),
                "vec shrink not simpler: {c:?}"
            );
        }
        assert!(vec![0.0f32; 1].shrink().is_empty(), "all-zero singleton is fully shrunk");
        // Matrix: smaller dims or zeroed data; fully-shrunk 1×1 zero stops.
        let m = Matrix::from_vec(4, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        for c in m.shrink() {
            assert!(
                c.rows * c.cols < m.rows * m.cols || c.data.iter().all(|&x| x == 0.0),
                "matrix shrink not simpler: {}x{}",
                c.rows,
                c.cols
            );
        }
        assert!(Matrix::zeros(1, 1).shrink().is_empty());
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn forall_shrink_minimizes_and_reports_steps() {
        // Fails whenever the vector has ≥ 3 elements: the shrinker must
        // descend through halvings and report the shrink trajectory.
        forall_shrink(
            "len >= 3 fails",
            Config { cases: 1, seed: 7 },
            |rng, _| Gen::vec_f32(rng, 64, 1.0),
            |v: &Vec<f32>| {
                if v.len() >= 3 {
                    Err(format!("len {}", v.len()))
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn forall_shrink_passes_clean_properties_silently() {
        forall_shrink(
            "dims in budget",
            Config { cases: 8, seed: 11 },
            |rng, _| Gen::dims(rng, 50, 6),
            |&(r, c): &(usize, usize)| {
                if r <= 50 && c <= 6 {
                    Ok(())
                } else {
                    Err(format!("({r}, {c})"))
                }
            },
        );
    }

    #[test]
    fn effective_cases_is_at_least_base() {
        // The CALOFOREST_PROP_CASES multiplier can only elevate.
        let cfg = Config { cases: 5, seed: 1 };
        assert!(cfg.effective_cases() >= 5);
        assert_eq!(cfg.effective_cases() % 5, 0);
    }
}
