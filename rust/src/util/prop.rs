//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a [`Rng`]-driven generated input; the runner
//! executes it for `cases` random cases and, on failure, re-reports the seed
//! so the case can be replayed deterministically. A light-weight shrink pass
//! for `Vec<f32>` inputs halves the input until the failure disappears.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `property(rng, case_index)`, panicking with the failing seed on error.
///
/// The property returns `Result<(), String>`; `Err` carries a description of
/// the violated invariant.
pub fn forall<F>(name: &str, cfg: Config, mut property: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).split(case as u64);
        if let Err(msg) = property(&mut rng, case) {
            panic!(
                "property '{name}' failed on case {case} (replay: seed={:#x}, split {case}): {msg}",
                cfg.seed
            );
        }
    }
}

/// Generator helpers for common tabular shapes.
pub struct Gen;

impl Gen {
    /// Random matrix dims: rows in [1, max_rows], cols in [1, max_cols].
    pub fn dims(rng: &mut Rng, max_rows: usize, max_cols: usize) -> (usize, usize) {
        (1 + rng.below(max_rows), 1 + rng.below(max_cols))
    }

    /// A vector of finite f32s in [-scale, scale], occasionally including
    /// exact zeros and repeated values (tree-split edge cases).
    pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            let r = rng.uniform();
            if r < 0.05 {
                v.push(0.0);
            } else if r < 0.10 && !v.is_empty() {
                let j = rng.below(v.len());
                v.push(v[j]); // duplicate an existing value
            } else {
                v.push(rng.range(-scale as f64, scale as f64) as f32);
            }
        }
        v
    }

    /// Class labels in [0, n_classes).
    pub fn labels(rng: &mut Rng, len: usize, n_classes: usize) -> Vec<u32> {
        (0..len).map(|_| rng.below(n_classes) as u32).collect()
    }
}

/// Assert two slices are elementwise close; returns Err description if not.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for i in 0..a.len() {
        let tol = atol + rtol * b[i].abs();
        if (a[i] - b[i]).abs() > tol || a[i].is_nan() != b[i].is_nan() {
            return Err(format!(
                "element {i}: {} vs {} (tol {tol}); context a[{}..{}]={:?}",
                a[i],
                b[i],
                i.saturating_sub(2),
                (i + 3).min(a.len()),
                &a[i.saturating_sub(2)..(i + 3).min(a.len())]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("uniform in range", Config::default(), |rng, _| {
            let u = rng.uniform();
            if (0.0..1.0).contains(&u) {
                Ok(())
            } else {
                Err(format!("u={u}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure() {
        forall("always fails", Config { cases: 2, seed: 1 }, |_, _| Err("nope".into()));
    }

    #[test]
    fn close_checks() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 1e-3).is_err());
    }

    #[test]
    fn gen_shapes() {
        let mut rng = Rng::new(2);
        let (r, c) = Gen::dims(&mut rng, 10, 5);
        assert!((1..=10).contains(&r) && (1..=5).contains(&c));
        let v = Gen::vec_f32(&mut rng, 100, 3.0);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|x| x.is_finite() && x.abs() <= 3.0));
        let y = Gen::labels(&mut rng, 50, 4);
        assert!(y.iter().all(|&l| l < 4));
    }
}
