//! Fig 3 / Fig 10: number of trees at the best validation iteration, by
//! timestep, across benchmark datasets, for FF/FD × SO/MO with n_ES=20-style
//! early stopping (scaled: n_tree=200, n_ES=8).

use caloforest::coordinator::memory::TrackingAlloc;
use caloforest::data::benchmark::{benchmark_registry, load_benchmark};
use caloforest::data::split::train_test_split;
use caloforest::forest::model::ModelKind;
use caloforest::forest::trainer::{train_forest, ForestTrainConfig};
use caloforest::gbt::{TrainParams, TreeKind};
use caloforest::util::bench::Bench;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Fig 3/10: best iteration by timestep under early stopping");
    let dataset_names: &[&str] = if quick {
        &["iris"]
    } else {
        &["iris", "seeds", "wine"]
    };
    let n_t = if quick { 4 } else { 10 };
    let registry = benchmark_registry();

    for &(kind, tree_kind, label) in &[
        (ModelKind::Flow, TreeKind::Single, "FF-SO"),
        (ModelKind::Flow, TreeKind::Multi, "FF-MO"),
        (ModelKind::Diffusion, TreeKind::Single, "FD-SO"),
        (ModelKind::Diffusion, TreeKind::Multi, "FD-MO"),
    ] {
        for name in dataset_names {
            let spec = registry.iter().find(|s| s.name == *name).unwrap();
            let data = load_benchmark(spec);
            let ((mut x, y), _) = train_test_split(&data.x, data.y.as_deref(), 0.2, 1);
            let mut y = y;
            if x.rows > 200 {
                x = x.take_rows(&(0..200).collect::<Vec<_>>());
                y = y.map(|l| l[..200].to_vec());
            }
            let cfg = ForestTrainConfig {
                kind,
                eps: if kind == ModelKind::Diffusion { 0.001 } else { 0.0 },
                n_t,
                k_dup: if quick { 4 } else { 10 },
                fresh_noise_validation: true,
                params: TrainParams {
                    n_trees: if quick { 30 } else { 100 },
                    max_depth: 7,
                    kind: tree_kind,
                    early_stopping_rounds: 8,
                    ..Default::default()
                },
                ..Default::default()
            };
            let ((_, report), _) =
                bench.time_once(&format!("{label} {name}"), || train_forest(&cfg, &x, y.as_deref()));
            let by_t = report.best_rounds_by_timestep(n_t);
            for (t_idx, rounds) in by_t.iter().enumerate() {
                bench.csv(
                    "method,dataset,t_index,t,best_rounds",
                    format!(
                        "{label},{name},{t_idx},{:.3},{rounds:.1}",
                        t_idx as f32 / (n_t - 1) as f32
                    ),
                );
            }
            println!(
                "{label:<6} {name:<22} best-rounds by t: {:?}",
                by_t.iter().map(|r| *r as usize).collect::<Vec<_>>()
            );
        }
    }
    bench.write_csv("fig3_early_stopping.csv");
    eprintln!("{}", bench.summary());
}
