//! Fig 2: memory usage *during* training, Original vs Ours, on the paper's
//! n=1000, p=100, n_y=10 configuration (scaled K/n_t by default).
//!
//! Original's curve is the byte-exact ledger timeline (monotone growth, the
//! paper's Question 2, with the shared-memory failure cross); ours is the
//! tracked allocator sampled during the run (flat after prepare).

use caloforest::coordinator::memory::{fmt_bytes, MemoryModel, TrackingAlloc};
use caloforest::coordinator::{run_training, RunOptions};
use caloforest::data::synthetic::synthetic_dataset;
use caloforest::forest::trainer::{prepare_opts, ForestTrainConfig, SpillConfig};
use caloforest::gbt::TrainParams;
use caloforest::original::{train_original, HostModel};
use caloforest::util::bench::Bench;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Fig 2: memory during training");
    let (n, p, n_y) = if quick { (200, 20, 4) } else { (1000, 100, 10) };
    let (x, y) = synthetic_dataset(n, p, n_y, 0);
    let cfg = ForestTrainConfig {
        n_t: if quick { 3 } else { 10 },
        k_dup: if quick { 3 } else { 10 },
        params: TrainParams { n_trees: if quick { 3 } else { 20 }, ..Default::default() },
        per_class_scaler: false,
        ..Default::default()
    };

    // Original: ledger timeline.
    let (orig, _) = bench.time_once("Original (ledger)", || {
        train_original(&cfg, &x, Some(&y), HostModel::default(), !quick)
    });
    for (i, (label, bytes)) in orig.timeline.iter().enumerate() {
        if i % (orig.timeline.len() / 60 + 1) == 0 {
            bench.csv(
                "impl,event_index,label,bytes",
                format!("Original,{i},{label},{bytes}"),
            );
        }
    }
    println!(
        "Original: peak {} (shm {}), failure: {:?}",
        fmt_bytes(orig.peak_bytes),
        fmt_bytes(orig.peak_shm_bytes),
        orig.failure
    );

    // Ours: allocator samples over time.
    let (ours, _) = bench.time_once("Ours (measured)", || {
        run_training(
            &cfg,
            &x,
            Some(&y),
            &RunOptions::new().with_workers(1).with_track_memory(true),
        )
    });
    for (i, (secs, bytes)) in ours.timeline.iter().enumerate() {
        bench.csv(
            "impl,event_index,label,bytes",
            format!("Ours,{i},t={secs:.2}s,{bytes}"),
        );
    }
    println!("Ours: peak {}", fmt_bytes(ours.peak_alloc_bytes));

    // The paper's Fig 2 shape claims, asserted:
    let growth: Vec<usize> = orig
        .timeline
        .iter()
        .filter(|(l, _)| l.starts_with('+'))
        .map(|&(_, b)| b)
        .collect();
    assert!(
        growth.windows(2).all(|w| w[1] >= w[0]),
        "Original's memory must grow monotonically during training"
    );
    assert!(
        orig.peak_bytes > ours.peak_alloc_bytes.max(1) * 3,
        "Original's footprint must dwarf ours"
    );

    // Virtual K-duplication at the paper's K=100: the shared training state
    // is the undup'd n·p matrix plus an O(1) noise-stream definition. Model
    // the *pre-virtual* shared block (the materialized f32 x0/x1 pair our
    // own implementation used to hold) with the byte ledger, and gate it
    // against the tracking allocator's *measured* peak across prepare() —
    // so a reintroduced n·K·p allocation, even a transient one, fails here
    // rather than only shrinking a closed-form ratio.
    let k_paper = 100;
    let mut old_shared = MemoryModel::new(None);
    old_shared.alloc("shared/x0_dup[f32]", n * k_paper * p * 4);
    old_shared.alloc("shared/x1_dup[f32]", n * k_paper * p * 4);
    let prep_cfg = ForestTrainConfig { k_dup: k_paper, ..cfg.clone() };
    let live_before = caloforest::coordinator::memory::current_bytes();
    caloforest::coordinator::memory::reset_peak();
    // Resident-explicit (`spill: None`): this gate measures the in-memory
    // layout; the spill plane gets its own gate below.
    let prep = prepare_opts(&prep_cfg, &x, Some(&y), None);
    let measured_peak = caloforest::coordinator::memory::peak_bytes()
        .saturating_sub(live_before)
        .max(prep.nbytes());
    let shrink = old_shared.peak as f64 / measured_peak.max(1) as f64;
    println!(
        "shared training state at K={k_paper}: materialized pair {} -> virtual {} held \
         (measured prepare peak {}, {shrink:.0}x)",
        fmt_bytes(old_shared.peak),
        fmt_bytes(prep.nbytes()),
        fmt_bytes(measured_peak),
    );
    bench.csv(
        "impl,event_index,label,bytes",
        format!("SharedState-materialized,0,K={k_paper},{}", old_shared.peak),
    );
    bench.csv(
        "impl,event_index,label,bytes",
        format!("SharedState-virtual-held,0,K={k_paper},{}", prep.nbytes()),
    );
    bench.csv(
        "impl,event_index,label,bytes",
        format!("SharedState-virtual-measured-peak,0,K={k_paper},{measured_peak}"),
    );
    assert!(
        shrink >= 100.0,
        "virtual duplication must shrink shared state >= 100x at K={k_paper}, got {shrink:.1}x \
         (measured prepare peak {measured_peak} B)"
    );

    // Out-of-core spill plane: with the scaled matrix spilled to the
    // file-backed column store, a training job's resident *input* is the u8
    // bin-code block for its duplicated span — a 4x reduction over the f32
    // x_t the resident plane materializes for the same job. Model the move
    // with the ledger (spill shifts the matrix off residency; chunks accrue
    // on disk) and gate both halves against the real spilled `Prepared`.
    let spill = SpillConfig::new(std::env::temp_dir().join("caloforest_fig2_spill"), 0);
    let live_before = caloforest::coordinator::memory::current_bytes();
    caloforest::coordinator::memory::reset_peak();
    let sprep = prepare_opts(&prep_cfg, &x, Some(&y), Some(&spill));
    let spilled_peak =
        caloforest::coordinator::memory::peak_bytes().saturating_sub(live_before);
    assert_eq!(sprep.nbytes(), 0, "spilled matrix must leave the resident ledger");
    assert!(sprep.disk_bytes() >= n * p * 4, "the scaled matrix must be on disk");

    let mut plane = MemoryModel::new(None);
    plane.alloc("shared/x_scaled[f32]", n * p * 4);
    plane.spill("shared/x_scaled[f32]");
    plane.alloc_disk("spill/chunks", sprep.disk_bytes() - plane.held_disk("shared/"));
    assert_eq!(plane.current, 0, "ledger residency must be empty after the spill");
    // Largest class job: resident f32 x_t vs the u8 codes that replace it.
    let (js, je) = *sprep
        .class_ranges
        .iter()
        .max_by_key(|(s, e)| e - s)
        .expect("at least one class");
    let xt_f32_bytes = (je - js) * k_paper * p * 4;
    let code_bytes = sprep.job_code_bytes(
        sprep.class_ranges.iter().position(|&r| r == (js, je)).unwrap(),
    );
    plane.alloc("job/codes[u8]", code_bytes);
    let code_shrink = xt_f32_bytes as f64 / plane.held("job/").max(1) as f64;
    println!(
        "spill plane: prepare peak {} resident ({} on disk); largest job input \
         {} as f32 x_t -> {} as u8 codes ({code_shrink:.2}x)",
        fmt_bytes(spilled_peak),
        fmt_bytes(sprep.disk_bytes()),
        fmt_bytes(xt_f32_bytes),
        fmt_bytes(code_bytes),
    );
    bench.csv(
        "impl,event_index,label,bytes",
        format!("SpillPlane-job-xt-f32,0,K={k_paper},{xt_f32_bytes}"),
    );
    bench.csv(
        "impl,event_index,label,bytes",
        format!("SpillPlane-job-codes-u8,0,K={k_paper},{code_bytes}"),
    );
    assert!(
        code_shrink >= 4.0 - 1e-9,
        "u8 codes must shrink the job's resident input >= 4x over f32 x_t, got {code_shrink:.2}x"
    );
    // At this n the matrix is smaller than one spill chunk, so the peak
    // bound is O(chunk): the column-major staging buffer plus its encoded
    // payload (and small bookkeeping) — never a second resident matrix.
    let chunk_bytes = caloforest::forest::trainer::SPILL_CHUNK_ROWS.min(n) * p * 4;
    assert!(
        spilled_peak <= 4 * chunk_bytes + (1 << 16),
        "spilled prepare peaked at {spilled_peak} B resident — must stay O(chunk) \
         (chunk is {chunk_bytes} B)"
    );

    bench.write_csv("fig2_memory_timeline.csv");
    eprintln!("{}", bench.summary());
}
