//! Fig 9: the jobs-vs-CPUs-per-job tradeoff. The paper varies
//! {40,20,10,4,2,1} parallel jobs × {1,2,4,10,20,40} CPUs each on a 40-CPU
//! box; this container has few cores, so we vary the worker count of the
//! coordinator's pool and report wall-clock + peak memory. The memory trend
//! (more concurrent jobs ⇒ more transient job state alive at once) is the
//! paper's point and reproduces at any core count; the time trend saturates
//! at the available cores (documented in EXPERIMENTS.md).

use caloforest::coordinator::memory::{fmt_bytes, reset_peak, peak_bytes, TrackingAlloc};
use caloforest::coordinator::{run_training, RunOptions};
use caloforest::data::synthetic::synthetic_dataset;
use caloforest::forest::trainer::ForestTrainConfig;
use caloforest::gbt::TrainParams;
use caloforest::util::bench::Bench;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Fig 9: parallel jobs vs memory/time");
    let (n, p, n_y) = if quick { (200, 5, 4) } else { (1000, 10, 10) };
    let (x, y) = synthetic_dataset(n, p, n_y, 0);
    let cfg = ForestTrainConfig {
        n_t: if quick { 3 } else { 10 },
        k_dup: if quick { 4 } else { 10 },
        params: TrainParams { n_trees: if quick { 4 } else { 20 }, ..Default::default() },
        ..Default::default()
    };

    println!("| workers | train (s) | peak heap |");
    println!("|---|---|---|");
    for workers in [1usize, 2, 4, 8] {
        reset_peak();
        let (out, secs) = bench.time_once(&format!("workers={workers}"), || {
            run_training(&cfg, &x, Some(&y), &RunOptions::new().with_workers(workers))
        });
        let peak = out.peak_alloc_bytes.max(peak_bytes());
        println!("| {workers} | {secs:.2} | {} |", fmt_bytes(peak));
        bench.csv(
            "workers,train_secs,peak_bytes",
            format!("{workers},{secs:.4},{peak}"),
        );
    }
    bench.write_csv("fig9_cpus_per_job.csv");
    eprintln!("{}", bench.summary());
}
