//! Fig 4: the 3×3 resource grid — training time / peak memory / generation
//! time as one of n, p, n_y varies — for Original, SO, MO, SO-ES, MO-ES.
//!
//! Scaled sweep values by default; CALOFOREST_PAPER_SCALE=1 restores the
//! paper's grids (Original points beyond feasibility are ledger-only).

use caloforest::coordinator::memory::TrackingAlloc;
use caloforest::experiments::resource::{run_point, SweepConfig, Variant, CSV_HEADER};
use caloforest::util::bench::Bench;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let paper = std::env::var("CALOFOREST_PAPER_SCALE").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Fig 4: resource sweeps over n, p, n_y");

    // §D.1 base point n=1000, p=10, n_y=10; sweep one axis at a time.
    let (base_n, base_p, base_ny) = (1000usize, 10usize, 10usize);
    let (ns, ps, nys): (Vec<usize>, Vec<usize>, Vec<usize>) = if quick {
        (vec![100, 300], vec![3, 10], vec![1, 3])
    } else if paper {
        (
            vec![100, 300, 1000, 3000, 10_000, 30_000, 100_000, 300_000],
            vec![3, 10, 30, 100, 300],
            vec![1, 3, 10, 30, 100],
        )
    } else {
        (vec![100, 300, 1000, 3000], vec![3, 10, 30], vec![1, 3, 10])
    };
    let cfg = SweepConfig {
        k_dup: if paper { 100 } else { 5 },
        n_t: if paper { 50 } else { 4 },
        n_trees: if paper { 100 } else { 6 },
        original_train_for_real: !paper,
        ..Default::default()
    };

    let mut sweep = |axis: &str, points: &[usize]| {
        for &v in points {
            let (n, p, n_y) = match axis {
                "n" => (v, base_p, base_ny),
                "p" => (base_n, v, base_ny),
                _ => (base_n, base_p, v),
            };
            for variant in Variant::all_fig4() {
                // MO at large p is the paper's own pain point; cap it.
                if matches!(variant, Variant::Mo | Variant::MoEs) && p > 100 && !paper {
                    continue;
                }
                let (r, _) = bench.time_once(
                    &format!("{} {axis}={v}", variant.name()),
                    || run_point(variant, n, p, n_y, &cfg),
                );
                bench.csv(
                    &format!("axis,{CSV_HEADER}"),
                    format!("{axis},{}", r.csv_row()),
                );
            }
        }
    };
    sweep("n", &ns);
    sweep("p", &ps);
    sweep("n_y", &nys);

    bench.write_csv("fig4_resource_sweeps.csv");
    eprintln!("{}", bench.summary());
}
