//! Tables 2 & 7: average rank of generated-data quality across benchmark
//! datasets and the full method panel (baselines + FD/FF × SO/MO ×
//! original/scaled hyperparameters).
//!
//! Defaults run a representative subset of the 27 stand-ins (the smallest
//! ones) with scaled hyperparameters; CALOFOREST_FULL=1 evaluates all 27
//! (hours on one CPU).

use caloforest::coordinator::memory::TrackingAlloc;
use caloforest::data::benchmark::benchmark_registry;
use caloforest::eval::rank::{average_ranks, Better};
use caloforest::experiments::quality::{evaluate_method, Method, Metrics, QualityConfig};
use caloforest::util::bench::{format_table, Bench};

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let full = std::env::var("CALOFOREST_FULL").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Table 2: average rank over benchmark datasets");
    let registry = benchmark_registry();
    let names: Vec<&str> = if quick {
        vec!["iris", "seeds"]
    } else if full {
        registry.iter().map(|s| s.name).collect()
    } else {
        vec!["iris", "seeds", "wine", "glass", "concrete_slump", "yacht_hydrodynamics"]
    };
    let methods = Method::all();
    let cfg = QualityConfig {
        row_cap: if quick { 100 } else { 250 },
        ..Default::default()
    };

    let mut per_metric: Vec<Vec<Vec<f64>>> = vec![Vec::new(); 8];
    for name in &names {
        let spec = registry.iter().find(|s| s.name == *name).unwrap();
        let mut rows = vec![Vec::with_capacity(methods.len()); 8];
        for method in methods {
            let (m, _) = bench.time_once(&format!("{name}/{}", method.name()), || {
                evaluate_method(method, spec, &cfg)
            });
            for (mi, v) in m.values().iter().enumerate() {
                rows[mi].push(*v);
                bench.csv(
                    "dataset,method,metric,value",
                    format!("{name},{},{},{v}", method.name(), Metrics::NAMES[mi]),
                );
            }
        }
        for mi in 0..8 {
            per_metric[mi].push(rows[mi].clone());
        }
    }

    // Rank aggregation (the published table format).
    let mut table: Vec<Vec<String>> = methods.iter().map(|m| vec![m.name().to_string()]).collect();
    let mut overall: Vec<Vec<f64>> = vec![Vec::new(); methods.len()];
    for mi in 0..8 {
        let better = if Metrics::higher_better(mi) { Better::Higher } else { Better::Lower };
        let agg = average_ranks(&per_metric[mi], better);
        for (mj, (mean, sem)) in agg.iter().enumerate() {
            table[mj].push(if mean.is_nan() || *mean == 0.0 {
                "—".into()
            } else {
                format!("{mean:.1}±{sem:.1}")
            });
            if mean.is_finite() && *mean > 0.0 {
                overall[mj].push(*mean);
            }
        }
    }
    for (mj, cells) in table.iter_mut().enumerate() {
        cells.push(format!("{:.1}", caloforest::util::stats::mean(&overall[mj])));
    }
    let mut header: Vec<&str> = vec!["method"];
    header.extend(Metrics::NAMES);
    header.push("Avg.");
    println!(
        "\n== Average rank over {} datasets (lower is better) ==\n{}",
        names.len(),
        format_table(&header, &table)
    );
    bench.write_csv("table2_benchmark_quality.csv");
    eprintln!("{}", bench.summary());
}
