//! Fig 11: K × n_tree × tree-structure (SO vs MO) ablation on the
//! connectionist_bench_sonar stand-in, reporting W1 to train and test.

use caloforest::coordinator::memory::TrackingAlloc;
use caloforest::data::benchmark::{benchmark_registry, load_benchmark};
use caloforest::data::split::train_test_split;
use caloforest::eval::wasserstein::w1_distance;
use caloforest::forest::trainer::{train_forest, ForestTrainConfig};
use caloforest::forest::{generate, GenerateConfig};
use caloforest::gbt::{TrainParams, TreeKind};
use caloforest::util::bench::Bench;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Fig 11: K / n_tree / SO-vs-MO ablation (sonar)");
    let spec = benchmark_registry()
        .into_iter()
        .find(|s| s.name == "connectionist_bench_sonar")
        .unwrap();
    let data = load_benchmark(&spec);
    let ((mut x, y), (x_test, _)) = train_test_split(&data.x, data.y.as_deref(), 0.2, 1);
    let mut y = y;
    // Sonar is p=60: cap rows so the K-sweep stays single-CPU feasible.
    if x.rows > 120 {
        x = x.take_rows(&(0..120).collect::<Vec<_>>());
        y = y.map(|l| l[..120].to_vec());
    }

    let ks: &[usize] = if quick { &[3] } else { &[3, 10, 30] };
    let trees: &[usize] = if quick { &[8] } else { &[10, 40] };
    println!("| structure | K | n_tree | W1_train | W1_test |");
    println!("|---|---|---|---|---|");
    for &(kind, label) in &[(TreeKind::Single, "SO"), (TreeKind::Multi, "MO")] {
        for &k in ks {
            for &n_tree in trees {
                let cfg = ForestTrainConfig {
                    n_t: if quick { 3 } else { 5 },
                    k_dup: k,
                    fresh_noise_validation: true,
                    params: TrainParams {
                        n_trees: n_tree,
                        max_depth: 6,
                        kind,
                        early_stopping_rounds: 6,
                        ..Default::default()
                    },
                    ..Default::default()
                };
                let ((model, _), _) = bench.time_once(
                    &format!("{label} K={k} n_tree={n_tree}"),
                    || train_forest(&cfg, &x, y.as_deref()),
                );
                let (gen, _) = generate(&model, &GenerateConfig::new(x.rows, 3));
                let w1_tr = w1_distance(&gen, &x, 12, 4);
                let w1_te = w1_distance(&gen, &x_test, 12, 5);
                println!("| {label} | {k} | {n_tree} | {w1_tr:.4} | {w1_te:.4} |");
                bench.csv(
                    "structure,k,n_tree,w1_train,w1_test",
                    format!("{label},{k},{n_tree},{w1_tr:.6},{w1_te:.6}"),
                );
            }
        }
    }
    bench.write_csv("fig11_ablations.csv");
    eprintln!("{}", bench.summary());
}
