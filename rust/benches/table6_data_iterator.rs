//! Table 6: Fig 1 data + the data-iterator variant — train time and peak
//! memory for Original / Ours / Ours-Iterator over n, plus a correctness
//! demonstration of the corrected (seeded) vs flawed (upstream) iterator.

use caloforest::coordinator::memory::{fmt_bytes, TrackingAlloc};
use caloforest::data::synthetic::synthetic_dataset;
use caloforest::experiments::resource::{run_point, SweepConfig, Variant, CSV_HEADER};
use caloforest::forest::dataiter::train_job_iterator;
use caloforest::forest::trainer::{prepare, train_job, ForestTrainConfig};
use caloforest::gbt::TrainParams;
use caloforest::util::bench::Bench;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Table 6: data-iterator variant");
    let ns: Vec<usize> = if quick { vec![300] } else { vec![300, 1000, 3000, 10_000] };
    let cfg = SweepConfig::default();

    println!("| variant | n | train (s) | peak mem |");
    println!("|---|---|---|---|");
    for &n in &ns {
        for variant in [Variant::Original, Variant::So, Variant::OursIterator] {
            let (r, _) = bench.time_once(&format!("{} n={n}", variant.name()), || {
                run_point(variant, n, 10, 10, &cfg)
            });
            println!(
                "| {} | {} | {:.2} | {} |",
                r.variant, n, r.train_secs, fmt_bytes(r.peak_bytes)
            );
            bench.csv(CSV_HEADER, r.csv_row());
        }
    }

    // Appendix B.3 correctness: the flawed iterator trains a *different*
    // (silently wrong) model vs the corrected one at identical seeds. Since
    // the virtual K-duplication refactor the corrected iterator reads the
    // same counter-based noise streams as the in-memory trainer, so it is
    // not merely close to the direct model — it is the *same* model.
    let (x, _) = synthetic_dataset(400, 5, 1, 3);
    let fc = ForestTrainConfig {
        n_t: 4,
        k_dup: 5,
        params: TrainParams { n_trees: 10, max_depth: 4, ..Default::default() },
        seed: 9,
        ..Default::default()
    };
    let prep = prepare(&fc, &x, None);
    let direct = train_job(&prep, &fc, 1, 0);
    let corrected = train_job_iterator(&prep, &fc, 1, 0, 5, false);
    let flawed = train_job_iterator(&prep, &fc, 1, 0, 5, true);
    let probe = caloforest::tensor::Matrix::randn(
        64,
        5,
        &mut caloforest::util::rng::Rng::new(4),
    );
    let d = direct.predict(&probe.view());
    let c = corrected.predict(&probe.view());
    let f = flawed.predict(&probe.view());
    let rmse = |a: &[f32], b: &[f32]| -> f64 {
        (a.iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / a.len() as f64)
            .sqrt()
    };
    let corr_vs_direct = rmse(&c.data, &d.data);
    let flawed_vs_direct = rmse(&f.data, &d.data);
    println!(
        "\niterator correctness: |corrected − direct| rmse = {corr_vs_direct:.4}, \
         |flawed − direct| rmse = {flawed_vs_direct:.4}"
    );
    bench.csv(
        "comparison,rmse",
        format!("corrected_vs_direct,{corr_vs_direct:.6}"),
    );
    bench.csv("comparison,rmse", format!("flawed_vs_direct,{flawed_vs_direct:.6}"));
    assert!(
        flawed_vs_direct > corr_vs_direct,
        "the flawed iterator must deviate more from the in-memory model"
    );
    assert_eq!(
        corr_vs_direct, 0.0,
        "the corrected iterator shares the in-memory path's noise streams and \
         must reproduce its model exactly"
    );
    bench.write_csv("table6_data_iterator.csv");
    eprintln!("{}", bench.summary());
}
