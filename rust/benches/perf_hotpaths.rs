//! §Perf: micro/meso benchmarks of the three hot paths used in the
//! performance pass — GBT histogram building & tree growth (L3 training),
//! batched forest prediction native vs packed vs XLA (generation), and the
//! noising data construction (training-data prep). Results feed
//! EXPERIMENTS.md §Perf.

use caloforest::coordinator::memory::{current_bytes, peak_bytes, reset_peak, TrackingAlloc};
use caloforest::coordinator::pool::{self as cpool, WorkerPool};
use caloforest::data::synthetic_dataset;
use caloforest::forest::noising;
use caloforest::forest::sampler::{
    generate, generate_batched, generate_with, Backend, GenerateConfig, Solver,
};
use caloforest::forest::schedule::VpSchedule;
use caloforest::forest::trainer::{prepare_opts, train_forest, ForestTrainConfig, SpillConfig};
use caloforest::forest::ModelKind;
use caloforest::gbt::booster::{update_eval_preds, update_train_preds};
use caloforest::gbt::histogram::{HistLayout, Histogram};
use caloforest::gbt::predict::PackedForest;
use caloforest::gbt::tree::PAR_BUILD_MIN_ROWS;
use caloforest::gbt::{
    BinnedMatrix, Booster, QuantForest, StreamingSketch, TileShape, TrainParams, TreeKind,
    SKETCH_BUDGET,
};
use caloforest::runtime::{xla_sampler::XlaField, PjrtRuntime};
use caloforest::tensor::Matrix;
use caloforest::util::bench::Bench;
use caloforest::util::rng::Rng;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    // `cargo bench --bench perf_hotpaths -- --test` runs the smoke-bench
    // mode used by CI: tiny sizes, but every timed path still executes, so
    // hot-path regressions (panics, shape mismatches) break the build.
    let test_mode = std::env::args().any(|a| a == "--test");
    let quick =
        test_mode || std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Perf hot paths").with_iters(1, if quick { 2 } else { 5 });
    let mut rng = Rng::new(0);

    // --- L3 training hot path: one booster train (hist build dominated). --
    let n = if quick { 2000 } else { 10_000 };
    let p = 20;
    let x = Matrix::randn(n, p, &mut rng);
    let mut targets = Matrix::zeros(n, p);
    for i in 0..n * p {
        targets.data[i] = x.data[i] * 0.5 + 0.1 * rng.normal_f32();
    }
    for (label, sub) in [("hist-subtraction ON", true), ("hist-subtraction OFF", false)] {
        let params = TrainParams {
            n_trees: 8,
            max_depth: 6,
            kind: TreeKind::Multi,
            hist_subtraction: sub,
            ..Default::default()
        };
        let m = bench.time(&format!("train MO n={n} p={p} [{label}]"), || {
            let b = Booster::train(&x.view(), &targets.view(), params, None);
            std::hint::black_box(b.n_nodes());
        });
        bench.csv(
            "path,label,mean_secs",
            format!("train,{label},{:.6}", m.mean()),
        );
    }

    // Intra-job parallelism: the same booster train with the two-level
    // engine's feature-parallel histograms / row-block updates engaged.
    let host = caloforest::coordinator::memory::host_cpus();
    for threads in [1usize, host.clamp(2, 8)] {
        let params = TrainParams {
            n_trees: 8,
            max_depth: 6,
            kind: TreeKind::Multi,
            intra_threads: threads,
            ..Default::default()
        };
        let m = bench.time(&format!("train MO n={n} p={p} [intra_threads={threads}]"), || {
            let b = Booster::train(&x.view(), &targets.view(), params, None);
            std::hint::black_box(b.n_nodes());
        });
        bench.csv(
            "path,label,mean_secs",
            format!("train,intra_threads={threads},{:.6}", m.mean()),
        );
    }

    // --- Dispatch overhead: per-call spawn/join vs persistent pool. -------
    // The worker-pool tentpole claim: park/unpark dispatch on a persistent
    // WorkerPool is strictly cheaper than per-call scoped spawn/join, which
    // is what let PAR_BUILD_MIN_ROWS drop below 1024.
    let workers = host.clamp(2, 8);
    let wp = WorkerPool::new(workers);
    let m_spawn = bench.time(&format!("dispatch spawn/join (w={workers}, trivial)"), || {
        cpool::for_each_chunk(workers, 64, 1, |_ci, r| {
            std::hint::black_box(r.start);
        });
    });
    let m_park = bench.time(&format!("dispatch park/unpark (w={workers}, trivial)"), || {
        wp.for_each_chunk(64, 1, |_ci, r| {
            std::hint::black_box(r.start);
        });
    });
    bench.csv("path,label,mean_secs", format!("dispatch,spawn-join,{:.9}", m_spawn.mean()));
    bench.csv("path,label,mean_secs", format!("dispatch,park-unpark,{:.9}", m_park.mean()));
    println!(
        "dispatch overhead: spawn/join {:.1} µs vs park/unpark {:.1} µs per call ({:.1}x)",
        m_spawn.mean() * 1e6,
        m_park.mean() * 1e6,
        m_spawn.mean() / m_park.mean().max(1e-12),
    );

    // Small-node histogram build (512 rows — below the old 1024-row
    // threshold): persistent-pool parallel build vs per-call pool
    // construction (the old spawn/join-per-node cost model) vs sequential.
    let small_n = 512;
    let sx = Matrix::randn(small_n, p, &mut rng);
    let sb = BinnedMatrix::fit_bin(&sx.view(), 255);
    let slayout = HistLayout::new(&sb);
    let srows: Vec<u32> = (0..small_n as u32).collect();
    let sgrads: Vec<f64> = (0..small_n).map(|i| (i % 7) as f64 - 3.0).collect();
    let mut shist = Histogram::new(&slayout, 1, true);
    let m_seq = bench.time(&format!("hist build n={small_n} sequential"), || {
        shist.build(&sb, &slayout, &srows, &sgrads, &[]);
        std::hint::black_box(shist.count[0]);
    });
    let m_pool = bench.time(&format!("hist build n={small_n} pooled (w={workers})"), || {
        shist.build_par(&sb, &slayout, &srows, &sgrads, &[], &wp);
        std::hint::black_box(shist.count[0]);
    });
    let m_fresh = bench.time(&format!("hist build n={small_n} spawn-per-call (w={workers})"), || {
        let fresh = WorkerPool::new(workers);
        shist.build_par(&sb, &slayout, &srows, &sgrads, &[], &fresh);
        std::hint::black_box(shist.count[0]);
    });
    bench.csv("path,label,mean_secs", format!("hist-small,sequential,{:.9}", m_seq.mean()));
    bench.csv("path,label,mean_secs", format!("hist-small,pooled,{:.9}", m_pool.mean()));
    bench.csv("path,label,mean_secs", format!("hist-small,spawn-per-call,{:.9}", m_fresh.mean()));
    bench.csv("path,label,value", "threshold,par_build_min_rows_before,1024".to_string());
    bench.csv(
        "path,label,value",
        format!("threshold,par_build_min_rows_after,{PAR_BUILD_MIN_ROWS}"),
    );
    println!(
        "small-node ({small_n} rows) hist build: seq {:.1} µs, pooled {:.1} µs, \
         spawn-per-call {:.1} µs; PAR_BUILD_MIN_ROWS 1024 -> {PAR_BUILD_MIN_ROWS}",
        m_seq.mean() * 1e6,
        m_pool.mean() * 1e6,
        m_fresh.mean() * 1e6,
    );

    // --- Generation hot path: booster vs packed vs XLA. -------------------
    let train_n = 400;
    let xt = Matrix::randn(train_n, 2, &mut rng);
    let mut yt = Matrix::zeros(train_n, 2);
    for r in 0..train_n {
        yt.set(r, 0, xt.at(r, 0) * 0.7);
        yt.set(r, 1, -xt.at(r, 1));
    }
    let booster = Booster::train(
        &xt.view(),
        &yt.view(),
        TrainParams { n_trees: 40, max_depth: 6, ..Default::default() },
        None,
    );
    let packed = PackedForest::pack(&booster);
    let batch = Matrix::randn(if quick { 2_000 } else { 20_000 }, 2, &mut rng);
    let mut out = vec![0.0f32; batch.rows * 2];
    let m1 = bench.time("predict native (tree-outer)", || {
        caloforest::gbt::predict::predict_batch(&booster, &batch.view(), &mut out);
        std::hint::black_box(out[0]);
    });
    let m2 = bench.time("predict packed (fixed-depth)", || {
        let r = packed.predict(&batch.view());
        std::hint::black_box(r.data[0]);
    });
    let predict_pool = WorkerPool::new(host);
    let mpar = bench.time(&format!("predict native parallel (workers={host})"), || {
        use caloforest::gbt::predict::predict_batch_par;
        predict_batch_par(&booster, &batch.view(), &mut out, &predict_pool);
        std::hint::black_box(out[0]);
    });
    bench.csv("path,label,mean_secs", format!("predict,native,{:.6}", m1.mean()));
    bench.csv("path,label,mean_secs", format!("predict,packed,{:.6}", m2.mean()));
    bench.csv("path,label,mean_secs", format!("predict,native-par,{:.6}", mpar.mean()));
    println!(
        "native {:.1} Mrow/s vs packed {:.1} Mrow/s vs native-par {:.1} Mrow/s",
        batch.rows as f64 / m1.mean() / 1e6,
        batch.rows as f64 / m2.mean() / 1e6,
        batch.rows as f64 / mpar.mean() / 1e6
    );

    // --- Sampler field-evaluation throughput: old vs blocked engine. ------
    // Generation evaluates one ensemble over the whole batch per
    // (t, y, step), so rows/sec of a single field evaluation bounds
    // sampling throughput. Old = predict_batch over six parallel node
    // vecs; blocked = the compiled NativeForest (contiguous 16-byte
    // breadth-first arena, row-block × tree-tile traversal). Outputs are
    // bit-identical; only the traversal differs.
    let engine = booster.compile();
    let pool8 = WorkerPool::new(8);
    let rows_n = batch.rows;
    let mut sampler_results: Vec<(&str, usize, f64)> = Vec::new();
    let m_old1 = bench.time("field-eval old (predict_batch, 1 thread)", || {
        caloforest::gbt::predict::predict_batch(&booster, &batch.view(), &mut out);
        std::hint::black_box(out[0]);
    });
    sampler_results.push(("predict_batch", 1, m_old1.mean()));
    let m_new1 = bench.time("field-eval blocked (NativeForest, 1 thread)", || {
        engine.predict_into(&batch.view(), &mut out);
        std::hint::black_box(out[0]);
    });
    sampler_results.push(("blocked", 1, m_new1.mean()));
    let m_old8 = bench.time("field-eval old (predict_batch_par, 8 threads)", || {
        caloforest::gbt::predict::predict_batch_par(&booster, &batch.view(), &mut out, &pool8);
        std::hint::black_box(out[0]);
    });
    sampler_results.push(("predict_batch_par", 8, m_old8.mean()));
    let m_new8 = bench.time("field-eval blocked (pooled, 8 threads)", || {
        engine.predict_into_pooled(&batch.view(), &mut out, &pool8);
        std::hint::black_box(out[0]);
    });
    sampler_results.push(("blocked-pooled", 8, m_new8.mean()));
    for &(backend, threads, secs) in &sampler_results {
        bench.csv(
            "path,label,mean_secs",
            format!("sampler-field-eval,{backend}-t{threads},{secs:.9}"),
        );
    }
    let speedup1 = m_old1.mean() / m_new1.mean().max(1e-12);
    let speedup8 = m_old8.mean() / m_new8.mean().max(1e-12);
    println!(
        "sampler field-eval: old {:.2} Mrow/s vs blocked {:.2} Mrow/s (1 thread, {speedup1:.2}x); \
         old {:.2} Mrow/s vs blocked {:.2} Mrow/s (8 threads, {speedup8:.2}x)",
        rows_n as f64 / m_old1.mean() / 1e6,
        rows_n as f64 / m_new1.mean() / 1e6,
        rows_n as f64 / m_old8.mean() / 1e6,
        rows_n as f64 / m_new8.mean() / 1e6,
    );
    // --- Arena engine: SIMD lanes vs scalar walk, autotuned vs default. ---
    // Same breadth-first arena, three traversals of the same batch: the
    // laned row-group walk (production), the scalar per-row reference walk,
    // and the laned walk pinned to the pre-autotuner DEFAULT tile shape.
    // All three are bit-identical by the parity gates; the deltas measure
    // what the lanes and the host-tuned blocking actually buy.
    let arena_shape = engine.shape();
    let engine_default = engine.clone().with_tile_shape(TileShape::DEFAULT);
    let mut arena_results: Vec<(&str, f64)> = Vec::new();
    let m_lanes = bench.time("arena laned walk (autotuned tiles, 1 thread)", || {
        engine.predict_into(&batch.view(), &mut out);
        std::hint::black_box(out[0]);
    });
    arena_results.push(("laned-autotuned", m_lanes.mean()));
    let m_scalar = bench.time("arena scalar walk (autotuned tiles, 1 thread)", || {
        engine.predict_into_scalar(&batch.view(), &mut out);
        std::hint::black_box(out[0]);
    });
    arena_results.push(("scalar-autotuned", m_scalar.mean()));
    let m_deftile = bench.time(
        &format!(
            "arena laned walk (default {}x{} tiles, 1 thread)",
            TileShape::DEFAULT.block_rows,
            TileShape::DEFAULT.tree_tile
        ),
        || {
            engine_default.predict_into(&batch.view(), &mut out);
            std::hint::black_box(out[0]);
        },
    );
    arena_results.push(("laned-default-tiles", m_deftile.mean()));
    for &(label, secs) in &arena_results {
        bench.csv("path,label,mean_secs", format!("arena-engine,{label},{secs:.9}"));
    }
    let lane_speedup = m_scalar.mean() / m_lanes.mean().max(1e-12);
    let tile_speedup = m_deftile.mean() / m_lanes.mean().max(1e-12);
    println!(
        "arena engine: scalar {:.2} vs laned {:.2} Mrow/s ({lane_speedup:.2}x lanes); \
         default-tile {:.2} vs autotuned {:.2} Mrow/s ({tile_speedup:.2}x, shape {}x{})",
        rows_n as f64 / m_scalar.mean() / 1e6,
        rows_n as f64 / m_lanes.mean() / 1e6,
        rows_n as f64 / m_deftile.mean() / 1e6,
        rows_n as f64 / m_lanes.mean() / 1e6,
        arena_shape.block_rows,
        arena_shape.tree_tile,
    );

    // --- Sampling service: solver ladder + request batcher. ---------------
    // The ladder trades steps for per-step order: Heun at n_t/2 and RK4 at
    // n_t/4 pay 2 and 4 field evaluations per step, so samples/sec tells
    // whether the fewer-steps rungs actually win wall-clock. The batcher
    // row measures what coalescing 64 small requests into shared batch
    // solves buys over serving each alone on the same warm pool.
    let svc_nt = 8;
    let (svc_x, svc_y) = synthetic_dataset(if quick { 150 } else { 400 }, 4, 2, 71);
    let svc_cfg = ForestTrainConfig {
        n_t: svc_nt,
        k_dup: 4,
        params: TrainParams {
            n_trees: if quick { 4 } else { 10 },
            max_depth: 4,
            ..Default::default()
        },
        seed: 73,
        ..Default::default()
    };
    let (svc_model, _) = train_forest(&svc_cfg, &svc_x, Some(&svc_y));
    svc_model.precompile();
    let svc_n_gen = if quick { 256 } else { 4096 };
    // (label, threads, mean_secs, samples).
    let mut svc_results: Vec<(String, usize, f64, usize)> = Vec::new();
    let ladder = [
        (Solver::Euler, svc_nt),
        (Solver::Heun, svc_nt / 2),
        (Solver::Rk4, svc_nt / 4),
    ];
    for (solver, steps) in ladder {
        for threads in [1usize, 8] {
            let mut gcfg = GenerateConfig::new(svc_n_gen, 17)
                .with_workers(threads)
                .with_solver(solver);
            if steps != svc_nt {
                gcfg = gcfg.with_n_t_override(steps);
            }
            let m = bench.time(
                &format!("generate {}@{steps} steps ({threads} thread)", solver.name()),
                || {
                    let (gx, _) = generate(&svc_model, &gcfg);
                    std::hint::black_box(gx.data[0]);
                },
            );
            bench.csv(
                "path,label,mean_secs",
                format!("sampling-solver,{}@{steps}-t{threads},{:.9}", solver.name(), m.mean()),
            );
            svc_results.push((format!("{}@{steps}", solver.name()), threads, m.mean(), svc_n_gen));
        }
    }
    let svc_mean = |label: &str, threads: usize| {
        svc_results
            .iter()
            .find(|(l, t, _, _)| l == label && *t == threads)
            .map(|&(_, _, s, _)| s)
            .unwrap_or(f64::NAN)
    };
    println!(
        "solver ladder (1 thread): euler@{svc_nt} {:.1} Ksample/s, heun@{} {:.1} Ksample/s, \
         rk4@{} {:.1} Ksample/s",
        svc_n_gen as f64 / svc_mean(&format!("euler@{svc_nt}"), 1) / 1e3,
        svc_nt / 2,
        svc_n_gen as f64 / svc_mean(&format!("heun@{}", svc_nt / 2), 1) / 1e3,
        svc_nt / 4,
        svc_n_gen as f64 / svc_mean(&format!("rk4@{}", svc_nt / 4), 1) / 1e3,
    );
    // Batcher: 64 small requests coalesced into shared-batch solves vs the
    // same requests served one by one on the same warm pool + field.
    let (svc_reqs, svc_req_rows) = if quick { (16usize, 16usize) } else { (64, 32) };
    let svc_field = svc_model.field(Backend::Compiled, &pool8);
    let svc_batch_cfgs: Vec<GenerateConfig> = (0..svc_reqs)
        .map(|i| GenerateConfig::new(svc_req_rows, 1000 + i as u64))
        .collect();
    let m_serial = bench.time(&format!("batcher serial {svc_reqs}x{svc_req_rows}"), || {
        for c in &svc_batch_cfgs {
            let (gx, _) = generate_with(&svc_model, &svc_field, c);
            std::hint::black_box(gx.data[0]);
        }
    });
    let m_coalesced = bench.time(&format!("batcher coalesced {svc_reqs}x{svc_req_rows}"), || {
        let out = generate_batched(&svc_model, &svc_field, &svc_batch_cfgs);
        std::hint::black_box(out[0].0.data[0]);
    });
    bench.csv("path,label,mean_secs", format!("sampling-batcher,serial,{:.9}", m_serial.mean()));
    bench.csv(
        "path,label,mean_secs",
        format!("sampling-batcher,coalesced,{:.9}", m_coalesced.mean()),
    );
    let batcher_speedup = m_serial.mean() / m_coalesced.mean().max(1e-12);
    println!(
        "batcher: {svc_reqs} requests × {svc_req_rows} rows serial {:.1} ms vs coalesced \
         {:.1} ms ({batcher_speedup:.2}x)",
        m_serial.mean() * 1e3,
        m_coalesced.mean() * 1e3,
    );

    // --- Training-update hot path: float references vs quantized engine. --
    // Every boosting round adds its new trees into the running train and
    // eval predictions. The float-raw reference walks raw thresholds
    // (`update_eval_preds`), the binned reference re-derives each visited
    // node's split bin by binary search per row (`update_train_preds`), and
    // the quantized engine compiles the round group once into a u8-bin
    // arena and traverses codes directly (`QuantForest`, the production
    // training path since this PR). Outputs are bit-identical; only the
    // routing differs.
    let upd_params = TrainParams {
        n_trees: 2,
        max_depth: 6,
        kind: TreeKind::Multi,
        ..Default::default()
    };
    let upd_binned = BinnedMatrix::fit_bin(&x.view(), upd_params.max_bins);
    let upd_booster = Booster::train_binned(&upd_binned, &targets.view(), upd_params, None);
    let group = &upd_booster.trees[..1]; // one Multi round group
    let upd_m = upd_booster.m;
    let upd_eta = upd_booster.params.eta;
    let upd_qf = QuantForest::compile_trees(
        group,
        TreeKind::Multi,
        upd_m,
        upd_eta,
        vec![0.0; upd_m],
        &upd_binned.cuts,
    );
    let mut upd_preds = vec![0.0f32; n * upd_m];
    let upd_pool1 = WorkerPool::new(1);
    let mut upd_results: Vec<(&str, usize, f64)> = Vec::new();
    for (threads, upd_pool) in [(1usize, &upd_pool1), (8, &pool8)] {
        let m_raw = bench.time(&format!("train-update float-raw ({threads} thread)"), || {
            update_eval_preds(
                group,
                &x.view(),
                &mut upd_preds,
                upd_m,
                TreeKind::Multi,
                upd_eta,
                upd_pool,
            );
            std::hint::black_box(upd_preds[0]);
        });
        upd_results.push(("float-raw", threads, m_raw.mean()));
        let m_ref = bench.time(&format!("train-update binned-ref ({threads} thread)"), || {
            update_train_preds(
                group,
                &upd_binned,
                &mut upd_preds,
                upd_m,
                TreeKind::Multi,
                upd_eta,
                upd_pool,
            );
            std::hint::black_box(upd_preds[0]);
        });
        upd_results.push(("binned-ref", threads, m_ref.mean()));
        let m_quant = bench.time(&format!("train-update quant ({threads} thread)"), || {
            upd_qf.accumulate_pooled(&upd_binned, &mut upd_preds, upd_pool);
            std::hint::black_box(upd_preds[0]);
        });
        upd_results.push(("quant", threads, m_quant.mean()));
    }
    for &(backend, threads, secs) in &upd_results {
        bench.csv(
            "path,label,mean_secs",
            format!("train-update,{backend}-t{threads},{secs:.9}"),
        );
    }
    let upd_mean = |backend: &str, threads: usize| {
        upd_results
            .iter()
            .find(|&&(b, t, _)| b == backend && t == threads)
            .map(|&(_, _, s)| s)
            .unwrap_or(f64::NAN)
    };
    let upd_speedup1 = upd_mean("binned-ref", 1) / upd_mean("quant", 1).max(1e-12);
    let upd_speedup8 = upd_mean("binned-ref", 8) / upd_mean("quant", 8).max(1e-12);
    println!(
        "train-update: binned-ref {:.2} vs quant {:.2} Mrow/s (1 thread, {upd_speedup1:.2}x); \
         binned-ref {:.2} vs quant {:.2} Mrow/s (8 threads, {upd_speedup8:.2}x)",
        n as f64 / upd_mean("binned-ref", 1) / 1e6,
        n as f64 / upd_mean("quant", 1) / 1e6,
        n as f64 / upd_mean("binned-ref", 8) / 1e6,
        n as f64 / upd_mean("quant", 8) / 1e6,
    );

    // --- Training data plane: virtual K-duplication. ----------------------
    // `prepare` now stores only the undup'd scaled matrix plus a noise-
    // stream definition (no n·K·p array), and each job's duplicated xt/z
    // is synthesized by the fused generate-noise+noising kernel, chunk-
    // parallel on the pool. Rows/sec here bounds how fast training data can
    // come to exist at all.
    let dp_n = if quick { 2_000 } else { 20_000 };
    let dp_p = 10;
    let dp_k = if quick { 8 } else { 64 };
    let dp_x = Matrix::randn(dp_n, dp_p, &mut rng);
    let dp_cfg = ForestTrainConfig { n_t: 2, k_dup: dp_k, seed: 3, ..Default::default() };
    // Resident-explicit (`spill: None`): this section measures the
    // in-memory layout and must not follow CALOFOREST_SPILL_MB; the spilled
    // plane is benchmarked in the out-of-core section below.
    let m_prep = bench.time(&format!("training prepare n={dp_n} p={dp_p} K={dp_k} (virtual)"), || {
        let prep = prepare_opts(&dp_cfg, &dp_x, None, None);
        std::hint::black_box(prep.nbytes());
    });
    let dp_prep = prepare_opts(&dp_cfg, &dp_x, None, None);
    let dup_rows = dp_n * dp_k;
    let mut dp_xt = Matrix::zeros(dup_rows, dp_p);
    let mut dp_z = Matrix::zeros(dup_rows, dp_p);
    // (stage, threads, mean_secs, rows-processed-per-call).
    let mut prep_results: Vec<(&str, usize, f64, usize)> =
        vec![("prepare", 1, m_prep.mean(), dp_n)];
    for (threads, dp_pool) in [(1usize, &upd_pool1), (8, &pool8)] {
        let m_jb = bench.time(&format!("job build (fused virtual noise, {threads} thread)"), || {
            noising::stream_inputs_targets(
                ModelKind::Flow,
                &dp_prep.x.row_slice(0, dp_n),
                0,
                &dp_prep.noise,
                0,
                dp_k,
                0.4,
                &dp_prep.schedule,
                &mut dp_xt,
                &mut dp_z,
                dp_pool,
            );
            std::hint::black_box(dp_xt.data[0]);
        });
        prep_results.push(("job-build", threads, m_jb.mean(), dup_rows));
    }
    for &(stage, threads, secs, _rows) in &prep_results {
        bench.csv(
            "path,label,mean_secs",
            format!("training-prepare,{stage}-t{threads},{secs:.9}"),
        );
    }
    let jb_mean = |threads: usize| {
        prep_results
            .iter()
            .find(|&&(s, th, _, _)| s == "job-build" && th == threads)
            .map(|&(_, _, m, _)| m)
            .unwrap_or(f64::NAN)
    };
    let jb_speedup = jb_mean(1) / jb_mean(8).max(1e-12);
    println!(
        "training data plane: prepare {:.2} Mrow/s; job build {:.2} Mrow/s (1 thread) vs \
         {:.2} Mrow/s (8 threads, {jb_speedup:.2}x) at K={dp_k}",
        dp_n as f64 / m_prep.mean() / 1e6,
        dup_rows as f64 / jb_mean(1) / 1e6,
        dup_rows as f64 / jb_mean(8) / 1e6,
    );

    // --- Out-of-core data plane: streaming sketch + spilled prepare. ------
    // The spilled plane's two prepare-side kernels: (1) the merge-and-prune
    // quantile sketch absorbing the matrix chunk-at-a-time (pass 1 of every
    // spilled job), and (2) `prepare` itself writing the scaled matrix into
    // the file-backed column store instead of holding it resident. Targets
    // (recorded under `out_of_core.targets`): spilled prepare keeps >= 0.5x
    // the resident prepare's throughput, at <= 0.3x its peak resident bytes.
    let oc_n = if quick { 10_000 } else { 200_000 };
    let oc_p = 10;
    let oc_chunk = 8192;
    let oc_x = Matrix::randn(oc_n, oc_p, &mut rng);
    // (stage, threads, mean_secs, rows-processed-per-call).
    let mut oc_results: Vec<(&str, usize, f64, usize)> = Vec::new();
    for (threads, oc_pool) in [(1usize, &upd_pool1), (8, &pool8)] {
        let m = bench.time(
            &format!("streaming sketch n={oc_n} p={oc_p} ({threads} thread)"),
            || {
                let mut sk = StreamingSketch::new(oc_p, 255);
                let mut r0 = 0;
                while r0 < oc_n {
                    let r1 = (r0 + oc_chunk).min(oc_n);
                    let chunk = oc_x.row_slice(r0, r1);
                    if threads == 1 {
                        sk.push_chunk(&chunk);
                    } else {
                        sk.push_chunk_pool(&chunk, oc_pool);
                    }
                    r0 = r1;
                }
                std::hint::black_box(sk.finish().n_features());
            },
        );
        oc_results.push(("streaming-sketch", threads, m.mean(), oc_n));
    }
    let oc_cfg = ForestTrainConfig { n_t: 2, k_dup: 8, seed: 5, ..Default::default() };
    let oc_spill = SpillConfig::new(std::env::temp_dir().join("caloforest_bench_spill"), 0);
    let oc_before = current_bytes();
    reset_peak();
    let m_oc_res = bench.time(&format!("prepare resident n={oc_n} p={oc_p}"), || {
        let prep = prepare_opts(&oc_cfg, &oc_x, None, None);
        std::hint::black_box(prep.nbytes());
    });
    let oc_resident_peak = peak_bytes().saturating_sub(oc_before);
    let oc_before = current_bytes();
    reset_peak();
    let m_oc_spill = bench.time(&format!("prepare spilled n={oc_n} p={oc_p}"), || {
        let prep = prepare_opts(&oc_cfg, &oc_x, None, Some(&oc_spill));
        std::hint::black_box(prep.disk_bytes());
    });
    let oc_spilled_peak = peak_bytes().saturating_sub(oc_before);
    oc_results.push(("prepare-resident", 1, m_oc_res.mean(), oc_n));
    oc_results.push(("prepare-spilled", 1, m_oc_spill.mean(), oc_n));
    for &(stage, threads, secs, _rows) in &oc_results {
        bench.csv("path,label,mean_secs", format!("out-of-core,{stage}-t{threads},{secs:.9}"));
    }
    let oc_tput_ratio = m_oc_res.mean() / m_oc_spill.mean().max(1e-12);
    let oc_peak_ratio = oc_spilled_peak as f64 / (oc_resident_peak as f64).max(1.0);
    let oc_mean = |stage: &str, threads: usize| {
        oc_results
            .iter()
            .find(|&&(s, th, _, _)| s == stage && th == threads)
            .map(|&(_, _, m, _)| m)
            .unwrap_or(f64::NAN)
    };
    println!(
        "out-of-core: sketch {:.2} Mrow/s (1 thread) vs {:.2} Mrow/s (8 threads); spilled \
         prepare {:.2}x resident throughput at {:.2}x resident peak ({} vs {} bytes)",
        oc_n as f64 / oc_mean("streaming-sketch", 1) / 1e6,
        oc_n as f64 / oc_mean("streaming-sketch", 8) / 1e6,
        oc_tput_ratio,
        oc_peak_ratio,
        oc_spilled_peak,
        oc_resident_peak,
    );

    // Full-size runs persist the trajectory at the workspace root (cargo
    // runs benches from the package dir, so anchor on the manifest path)
    // where the committed file lives; smoke/--test runs use tiny sizes and
    // must not overwrite the recorded baseline.
    if !quick {
        use caloforest::util::Json;
        let row_json = |rows: usize, backend: &str, threads: usize, secs: f64| {
            let mut o = Json::obj();
            o.set("backend", backend)
                .set("threads", threads)
                .set("mean_secs", secs)
                .set("rows_per_sec", rows as f64 / secs.max(1e-12));
            o
        };
        let mut sampler_sec = Json::obj();
        let results = sampler_results
            .iter()
            .map(|&(backend, threads, secs)| row_json(rows_n, backend, threads, secs))
            .collect::<Vec<_>>();
        let mut config = Json::obj();
        config
            .set("rows", rows_n)
            .set("features", batch.cols)
            .set("trees", booster.trees.len())
            .set("max_depth", booster.params.max_depth)
            .set("outputs", booster.m);
        sampler_sec
            .set("config", config)
            .set("results", Json::Arr(results))
            .set("single_thread_speedup", speedup1)
            .set("pooled_speedup", speedup8);
        let mut arena_sec = Json::obj();
        let results = arena_results
            .iter()
            .map(|&(label, secs)| row_json(rows_n, label, 1, secs))
            .collect::<Vec<_>>();
        let mut config = Json::obj();
        config
            .set("rows", rows_n)
            .set("trees", booster.trees.len())
            .set("max_depth", booster.params.max_depth)
            .set("autotuned_block_rows", arena_shape.block_rows)
            .set("autotuned_tree_tile", arena_shape.tree_tile)
            .set("default_block_rows", TileShape::DEFAULT.block_rows)
            .set("default_tree_tile", TileShape::DEFAULT.tree_tile);
        arena_sec
            .set("config", config)
            .set("results", Json::Arr(results))
            .set("lane_speedup", lane_speedup)
            .set("autotune_speedup_vs_default", tile_speedup);
        let mut upd_sec = Json::obj();
        let results = upd_results
            .iter()
            .map(|&(backend, threads, secs)| row_json(n, backend, threads, secs))
            .collect::<Vec<_>>();
        let mut config = Json::obj();
        config
            .set("rows", n)
            .set("features", p)
            .set("trees_per_round", group.len())
            .set("max_depth", upd_booster.params.max_depth)
            .set("outputs", upd_m)
            .set("kind", "Multi");
        upd_sec
            .set("config", config)
            .set("results", Json::Arr(results))
            .set("quant_speedup_1t", upd_speedup1)
            .set("quant_speedup_8t", upd_speedup8);
        let mut prep_sec = Json::obj();
        let results = prep_results
            .iter()
            .map(|&(stage, threads, secs, rows)| row_json(rows, stage, threads, secs))
            .collect::<Vec<_>>();
        let mut config = Json::obj();
        config
            .set("rows", dp_n)
            .set("features", dp_p)
            .set("k_dup", dp_k)
            .set("dup_rows", dup_rows);
        prep_sec
            .set("config", config)
            .set("results", Json::Arr(results))
            .set("job_build_speedup_8t", jb_speedup);
        let mut oc_sec = Json::obj();
        let results = oc_results
            .iter()
            .map(|&(stage, threads, secs, rows)| row_json(rows, stage, threads, secs))
            .collect::<Vec<_>>();
        let mut oc_config = Json::obj();
        oc_config
            .set("rows", oc_n)
            .set("features", oc_p)
            .set("chunk_rows", oc_chunk)
            .set("sketch_budget", SKETCH_BUDGET);
        let mut oc_prepare = Json::obj();
        oc_prepare
            .set("resident_secs", m_oc_res.mean())
            .set("spilled_secs", m_oc_spill.mean())
            .set("spilled_throughput_ratio", oc_tput_ratio)
            .set("resident_peak_bytes", oc_resident_peak)
            .set("spilled_peak_bytes", oc_spilled_peak)
            .set("spilled_peak_ratio", oc_peak_ratio);
        let mut oc_targets = Json::obj();
        oc_targets
            .set("spilled_prepare_min_throughput_ratio", 0.5)
            .set("spilled_peak_max_ratio", 0.3);
        oc_sec
            .set("config", oc_config)
            .set("results", Json::Arr(results))
            .set("prepare", oc_prepare)
            .set("targets", oc_targets);
        let mut svc_sec = Json::obj();
        let results = svc_results
            .iter()
            .map(|(label, threads, secs, samples)| {
                let mut o = Json::obj();
                o.set("solver", label.as_str())
                    .set("threads", *threads)
                    .set("mean_secs", *secs)
                    .set("samples_per_sec", *samples as f64 / secs.max(1e-12));
                o
            })
            .collect::<Vec<_>>();
        let mut svc_config = Json::obj();
        svc_config
            .set("n_t", svc_nt)
            .set("samples_per_call", svc_n_gen)
            .set("features", svc_x.cols)
            .set("classes", 2usize);
        let mut batcher = Json::obj();
        batcher
            .set("requests", svc_reqs)
            .set("rows_per_request", svc_req_rows)
            .set("serial_secs", m_serial.mean())
            .set("coalesced_secs", m_coalesced.mean())
            .set("coalescing_speedup", batcher_speedup);
        svc_sec
            .set("config", svc_config)
            .set("solver_ladder", Json::Arr(results))
            .set("batcher", batcher);
        let mut doc = Json::obj();
        doc.set("bench", "perf_hotpaths")
            .set("status", "measured")
            .set("sampler_field_eval", sampler_sec)
            .set("arena_engine", arena_sec)
            .set("training_update", upd_sec)
            .set("training_prepare", prep_sec)
            .set("out_of_core", oc_sec)
            .set("sampling_service", svc_sec);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|root| root.join("BENCH_sampling.json"))
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_sampling.json"));
        if std::fs::write(&path, doc.pretty()).is_ok() {
            eprintln!("  [bench] wrote {}", path.display());
        }
    }

    // XLA path at its pinned batch (per-call latency matters for L3).
    if let Ok(runtime) = PjrtRuntime::cpu(std::path::Path::new("artifacts")) {
        // Wrap the booster in a 1×1 model grid to reuse XlaField.
        let model = single_slot_model(booster.clone());
        match XlaField::prepare(&runtime, &model) {
            Ok(field) => {
                use caloforest::forest::sampler::FieldEval;
                let xb = Matrix::randn(field.batch_rows(), 2, &mut rng);
                let mut xout = vec![0.0f32; xb.rows * 2];
                let m3 = bench.time("predict xla (PJRT, pinned batch)", || {
                    field.eval(0, 0, &xb.view(), &mut xout);
                    std::hint::black_box(xout[0]);
                });
                bench.csv("path,label,mean_secs", format!("predict,xla,{:.6}", m3.mean()));
                println!(
                    "xla {:.1} Krow/s at batch {}",
                    xb.rows as f64 / m3.mean() / 1e3,
                    xb.rows
                );
            }
            Err(e) => eprintln!("xla predict skipped: {e}"),
        }
    }

    // --- Noising data construction. ---------------------------------------
    let big = Matrix::randn(if quick { 20_000 } else { 200_000 }, 10, &mut rng);
    let noise = Matrix::randn(big.rows, 10, &mut rng);
    let mut xt_buf = Matrix::zeros(big.rows, 10);
    let sched = VpSchedule::default();
    let m4 = bench.time("noising cfm_inputs", || {
        noising::cfm_inputs(&big.view(), &noise.view(), 0.4, &mut xt_buf);
        std::hint::black_box(xt_buf.data[0]);
    });
    let m5 = bench.time("noising diffusion_inputs", || {
        noising::diffusion_inputs(&big.view(), &noise.view(), 0.4, &sched, &mut xt_buf);
        std::hint::black_box(xt_buf.data[0]);
    });
    let gbs = |m: &caloforest::util::bench::Measurement| {
        (big.nbytes() * 3) as f64 / m.mean() / 1e9
    };
    println!("noising cfm {:.2} GB/s, vp {:.2} GB/s", gbs(&m4), gbs(&m5));
    bench.csv("path,label,mean_secs", format!("noising,cfm,{:.6}", m4.mean()));
    bench.csv("path,label,mean_secs", format!("noising,vp,{:.6}", m5.mean()));

    bench.write_csv("perf_hotpaths.csv");
    eprintln!("{}", bench.summary());
}

fn single_slot_model(booster: Booster) -> caloforest::forest::ForestModel {
    use caloforest::forest::model::{ForestModel, ModelKind};
    use caloforest::forest::scaler::{ClassScalers, MinMaxScaler};
    use caloforest::forest::schedule::TimeGrid;
    let mut model = ForestModel::empty(
        ModelKind::Flow,
        TimeGrid::uniform(2, 0.0),
        VpSchedule::default(),
        ClassScalers {
            scalers: vec![MinMaxScaler {
                mins: vec![-1.0; 2],
                maxs: vec![1.0; 2],
                lo: -1.0,
                hi: 1.0,
            }],
            per_class: false,
        },
        vec![1],
        2,
    );
    model.set_ensemble(0, 0, booster.clone());
    model.set_ensemble(1, 0, booster);
    model
}
