//! Fig 1 + Table 6 (left columns): training time and peak memory vs n for
//! Original vs Ours, p=100, n_y=10.
//!
//! Scaled defaults (K=10, n_t=10, n ≤ 10k); set CALOFOREST_PAPER_SCALE=1
//! for the published K=100/n_t=50 grid (Original is then ledger-only).
//!
//! Ours' measured peak reflects virtual K-duplication: the *shared*
//! training state is the undup'd `n·p` matrix plus an O(1) noise-stream
//! definition (no `2·n·K·p` materialized x0/x1 pair). Per-job transients —
//! one job's xt/z, `2·n_class·K·p` floats — remain O(K) and now dominate
//! the measured curve; they are freed as each job completes, unlike the
//! old shared pair which lived for the whole run. Original's ledger still
//! charges the paper's full materialization closed forms.

use caloforest::coordinator::memory::{fmt_bytes, TrackingAlloc};
use caloforest::experiments::resource::{run_point, SweepConfig, Variant, CSV_HEADER};
use caloforest::util::bench::Bench;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let paper = std::env::var("CALOFOREST_PAPER_SCALE").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Fig 1: train time & peak memory vs n (Original vs Ours)");

    // Default p is scaled 100 → 30 to fit a single-CPU budget (the paper's
    // memory story is a function of n·p and reproduces at any p; paper
    // scale restores p=100).
    let p = if paper { 100 } else { 30 };
    let ns: Vec<usize> = if quick {
        vec![100, 300]
    } else if paper {
        vec![1000, 3000, 10_000, 30_000, 100_000]
    } else {
        vec![300, 1000, 3000]
    };
    let cfg = SweepConfig {
        k_dup: if paper { 100 } else { 5 },
        n_t: if paper { 50 } else { 4 },
        n_trees: if paper { 100 } else { 6 },
        original_train_for_real: !paper,
        ..Default::default()
    };

    println!("| variant | n | train (s) | peak mem | gen 5n (s) |");
    println!("|---|---|---|---|---|");
    for &n in &ns {
        for variant in [Variant::Original, Variant::So] {
            let (r, _) = bench.time_once(&format!("{} n={n}", variant.name()), || {
                run_point(variant, n, p, 10, &cfg)
            });
            println!(
                "| {} | {} | {:.2} | {} | {} |",
                r.variant,
                n,
                r.train_secs,
                fmt_bytes(r.peak_bytes),
                r.gen_secs.map(|g| format!("{g:.2}")).unwrap_or_else(|| "✗".into())
            );
            bench.csv(CSV_HEADER, r.csv_row());
        }
    }
    bench.write_csv("fig1_scaling_n.csv");
    eprintln!("{}", bench.summary());
}
