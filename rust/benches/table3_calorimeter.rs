//! Tables 3/4/5 + Figs 5–8: CaloForest on the calorimeter stand-ins —
//! χ² separation per high-level feature, classifier AUC, and the §4.3
//! resource numbers, for both Photons and Pions.

use caloforest::coordinator::memory::TrackingAlloc;
use caloforest::experiments::calo::{photons_mini, pions_mini, run_caloforest, CaloConfig};
use caloforest::sim::CaloGeometry;
use caloforest::util::bench::Bench;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn main() {
    let quick = std::env::var("CALOFOREST_BENCH_QUICK").ok().as_deref() == Some("1");
    let full = std::env::var("CALOFOREST_FULL_GEOMETRY").ok().as_deref() == Some("1");
    let mut bench = Bench::new("Tables 3/4/5: CaloForest on Photons & Pions");
    let cfg = CaloConfig {
        n_per_class: if quick { 10 } else { 30 },
        n_t: if quick { 3 } else { 6 },
        k_dup: if quick { 2 } else { 5 },
        n_trees: if quick { 5 } else { 12 },
        ..Default::default()
    };

    for (particle, geometry) in [
        ("photons", if full { CaloGeometry::photons() } else { photons_mini() }),
        ("pions", if full { CaloGeometry::pions() } else { pions_mini() }),
    ] {
        let (out, _) = bench.time_once(&format!("caloforest {particle}"), || {
            run_caloforest(&geometry, &cfg)
        });
        println!("\n== {particle} (p = {}) ==", geometry.n_voxels());
        println!("| feature | chi2 separation |");
        println!("|---|---|");
        for (name, v) in &out.chi2 {
            println!("| {name} | {v:.4} |");
            bench.csv(
                "particle,feature,chi2",
                format!("{particle},{name},{v:.6}"),
            );
        }
        println!("AUC = {:.4}", out.auc);
        println!(
            "train {:.1}s | {} ensembles | gen {:.3} ms/shower",
            out.train_secs, out.ensembles_trained, out.ms_per_datapoint
        );
        bench.csv(
            "particle,feature,chi2",
            format!("{particle},AUC,{:.6}", out.auc),
        );
        bench.csv(
            "particle,feature,chi2",
            format!("{particle},ms_per_datapoint,{:.6}", out.ms_per_datapoint),
        );
        // Figs 5/8: histogram dumps.
        let mut csv = String::from("feature,bin_center,reference,generated\n");
        for (feature, center, r, g) in &out.histograms {
            csv.push_str(&format!("{feature},{center},{r},{g}\n"));
        }
        std::fs::create_dir_all("results").ok();
        std::fs::write(format!("results/fig5_8_{particle}_histograms.csv"), csv).ok();
    }
    bench.write_csv("table3_calorimeter.csv");
    eprintln!("{}", bench.summary());
}
