//! End-to-end driver (the EXPERIMENTS.md validation run): the full
//! CaloForest pipeline on the Geant4 stand-in — simulate showers, train the
//! ForestFlow grid with per-class scalers, generate a full dataset, and
//! report the Challenge metrics (χ² separation powers + classifier AUC).
//!
//! Default runs the reduced geometry (62 voxels × 15 energies) in ~a minute
//! on one CPU. `--full-geometry` restores the Challenge's 368 voxels.
//!
//! Run: `cargo run --release --example calorimeter [-- --particle pions]`

use caloforest::experiments::calo::{photons_mini, pions_mini, run_caloforest, CaloConfig};
use caloforest::sim::CaloGeometry;
use caloforest::util::bench::format_table;
use caloforest::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("calorimeter", "end-to-end CaloForest driver")
        .opt("particle", "photons", "photons | pions")
        .opt("n-per-class", "30", "showers per incident-energy class")
        .opt("n-t", "6", "timesteps n_t")
        .opt("k", "5", "duplication K")
        .opt("n-tree", "12", "trees per ensemble")
        .opt("workers", "1", "parallel jobs")
        .opt("seed", "0", "seed")
        .flag("full-geometry", "full Challenge voxelization (368/533)")
        .parse(&argv)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });

    let particle = args.get("particle");
    let geometry = match (particle.as_str(), args.get_bool("full-geometry")) {
        ("pions", true) => CaloGeometry::pions(),
        ("pions", false) => pions_mini(),
        (_, true) => CaloGeometry::photons(),
        (_, false) => photons_mini(),
    };
    let cfg = CaloConfig {
        n_per_class: args.get_usize("n-per-class"),
        n_t: args.get_usize("n-t"),
        k_dup: args.get_usize("k"),
        n_trees: args.get_usize("n-tree"),
        workers: args.get_usize("workers"),
        seed: args.get_u64("seed"),
        ..Default::default()
    };
    println!(
        "CaloForest on {} ({} voxels, {} classes, {} showers/class)",
        particle,
        geometry.n_voxels(),
        geometry.n_classes(),
        cfg.n_per_class
    );

    let out = run_caloforest(&geometry, &cfg);

    // Table 3-style summary.
    println!("\n== Challenge metrics ({particle}) ==");
    println!("classifier AUC (lower = more realistic): {:.4}", out.auc);
    let rows: Vec<Vec<String>> = out
        .chi2
        .iter()
        .map(|(name, v)| vec![name.clone(), format!("{v:.4}")])
        .collect();
    println!("{}", format_table(&["feature", "chi2 separation"], &rows));
    println!(
        "resources: train {:.1}s | {} ensembles | gen {:.2}s = {:.3} ms/shower",
        out.train_secs, out.ensembles_trained, out.gen_secs, out.ms_per_datapoint
    );

    // Persist the histogram CSV for the Fig 5/8 plots.
    std::fs::create_dir_all("results").ok();
    let mut csv = String::from("feature,bin_center,reference,generated\n");
    for (feature, center, r, g) in &out.histograms {
        csv.push_str(&format!("{feature},{center},{r},{g}\n"));
    }
    let path = format!("results/calorimeter_{particle}_histograms.csv");
    std::fs::write(&path, csv).expect("write histograms");
    println!("feature histograms -> {path}");
    println!("calorimeter example OK");
}
