//! Benchmark-quality mini-suite: evaluate a panel of generators on several
//! Table-8 stand-ins and print average ranks, Table-2 style.
//!
//! Run: `cargo run --release --example benchmark_suite [-- --datasets iris,wine,seeds]`

use caloforest::eval::rank::{average_ranks, Better};
use caloforest::experiments::quality::{evaluate_method, Method, Metrics, QualityConfig};
use caloforest::util::bench::format_table;
use caloforest::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::new("benchmark_suite", "Table-2-style average ranks")
        .opt("datasets", "iris,seeds,wine,glass", "comma-separated stand-ins")
        .opt("row-cap", "150", "training-row cap")
        .parse(&argv)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });

    let registry = caloforest::data::benchmark::benchmark_registry();
    let specs: Vec<_> = args
        .get("datasets")
        .split(',')
        .filter_map(|n| registry.iter().find(|s| s.name == n.trim()).cloned())
        .collect();
    assert!(!specs.is_empty(), "no known datasets selected");
    let methods = [
        Method::GaussianCopula,
        Method::Tvae,
        Method::TabDdpm,
        Method::FfOriginal,
        Method::FfSoScaled,
        Method::FfMoScaled,
    ];
    let cfg = QualityConfig { row_cap: args.get_usize("row-cap"), ..Default::default() };

    // metric -> dataset -> method value
    let mut per_metric: Vec<Vec<Vec<f64>>> = vec![Vec::new(); 8];
    for spec in &specs {
        eprintln!("dataset {} (n={}, p={}, n_y={})", spec.name, spec.n, spec.p, spec.n_y);
        let mut row_per_metric = vec![Vec::with_capacity(methods.len()); 8];
        for method in methods {
            let t0 = std::time::Instant::now();
            let m = evaluate_method(method, spec, &cfg);
            eprintln!("  {:<16} {:.1}s", method.name(), t0.elapsed().as_secs_f64());
            for (mi, v) in m.values().iter().enumerate() {
                row_per_metric[mi].push(*v);
            }
        }
        for mi in 0..8 {
            per_metric[mi].push(row_per_metric[mi].clone());
        }
    }

    // Average rank per metric + overall (the Table 2 presentation).
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut overall = vec![Vec::new(); methods.len()];
    let mut table: Vec<Vec<String>> =
        methods.iter().map(|m| vec![m.name().to_string()]).collect();
    for mi in 0..8 {
        let better = if Metrics::higher_better(mi) { Better::Higher } else { Better::Lower };
        let agg = average_ranks(&per_metric[mi], better);
        for (mj, (mean, sem)) in agg.iter().enumerate() {
            table[mj].push(if mean.is_nan() || *mean == 0.0 {
                "—".to_string()
            } else {
                format!("{mean:.1}±{sem:.1}")
            });
            if !mean.is_nan() && *mean > 0.0 {
                overall[mj].push(*mean);
            }
        }
    }
    for (mj, mut cells) in table.into_iter().enumerate() {
        let avg = caloforest::util::stats::mean(&overall[mj]);
        cells.push(format!("{avg:.1}"));
        rows.push(cells);
    }
    let mut header: Vec<&str> = vec!["method"];
    header.extend(Metrics::NAMES);
    header.push("Avg.");
    println!("\n== Average rank over {} datasets (lower is better) ==", specs.len());
    println!("{}", format_table(&header, &rows));
}
