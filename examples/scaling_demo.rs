//! Scaling demo (the Fig 1/2 story in one run): train the Original
//! implementation and ours on the same dataset, print training time, the
//! memory each would need, and where Original fails on the paper's
//! workstation model.
//!
//! Run: `cargo run --release --example scaling_demo`

use caloforest::coordinator::memory::fmt_bytes;
use caloforest::experiments::resource::{run_point, SweepConfig, Variant};
use caloforest::original::{train_original, HostModel};
use caloforest::util::bench::format_table;

fn main() {
    // The paper's Fig 2 configuration, scaled 10×: n=1000, p=100, n_y=10
    // at K=100/n_t=50 becomes K=10/n_t=10 here; the *ratios* are preserved.
    let cfg = SweepConfig { k_dup: 10, n_t: 10, n_trees: 20, ..Default::default() };
    let (n, p, n_y) = (1000usize, 100usize, 10usize);

    println!("dataset: n={n}, p={p}, n_y={n_y}, K={}, n_t={}", cfg.k_dup, cfg.n_t);

    let mut rows = Vec::new();
    for variant in [Variant::Original, Variant::So, Variant::SoEs, Variant::Mo] {
        let r = run_point(variant, n, p, n_y, &cfg);
        rows.push(vec![
            r.variant.to_string(),
            format!("{:.2}s", r.train_secs),
            fmt_bytes(r.peak_bytes),
            r.gen_secs.map(|g| format!("{g:.2}s")).unwrap_or_else(|| "✗".into()),
            if r.failed { "FAILED".into() } else { "ok".into() },
        ]);
    }
    println!(
        "{}",
        format_table(&["variant", "train", "peak memory", "gen (5×n)", "status"], &rows)
    );
    println!("(Original's memory is the byte-exact ledger of the upstream numpy/joblib");
    println!(" implementation; ours is the measured allocator peak.)\n");

    // Where does the Original fail on the paper's workstation? Find the
    // smallest n (at paper-scale K=100, n_t=50) whose ledger exceeds the
    // 189 GiB shared-memory cap — the Fig 1 red cross.
    println!("Original-implementation failure threshold at paper scale (K=100, n_t=50):");
    let paper_cfg = caloforest::forest::ForestTrainConfig {
        n_t: 50,
        k_dup: 100,
        params: caloforest::gbt::TrainParams { n_trees: 100, ..Default::default() },
        per_class_scaler: false,
        ..Default::default()
    };
    for n_probe in [1_000usize, 3_000, 10_000, 30_000, 100_000] {
        let (x, y) =
            caloforest::data::synthetic::synthetic_dataset(n_probe, 100, 10, 0);
        let out = train_original(&paper_cfg, &x, Some(&y), HostModel::default(), false);
        println!(
            "  n={n_probe:>7}: ledger peak {:>12}  shm peak {:>12}  -> {}",
            fmt_bytes(out.peak_bytes),
            fmt_bytes(out.peak_shm_bytes),
            match out.failure {
                Some(f) => format!("FAILS ({f:?})"),
                None => "fits".into(),
            }
        );
    }
    println!("scaling_demo OK");
}
