//! Quickstart: train ForestFlow on a small 2-D two-cluster dataset,
//! generate samples with both the native and the AOT XLA (PJRT) backend,
//! and verify they agree — the minimal end-to-end tour of all three layers.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use caloforest::coordinator::{run_training, RunOptions};
use caloforest::eval::wasserstein::w1_distance;
use caloforest::forest::sampler::{generate, generate_with, GenerateConfig};
use caloforest::forest::trainer::ForestTrainConfig;
use caloforest::gbt::{TrainParams, TreeKind};
use caloforest::runtime::{xla_sampler::XlaField, PjrtRuntime};
use caloforest::tensor::Matrix;
use caloforest::util::rng::Rng;
use std::path::Path;

fn main() {
    // 1. A toy dataset: two Gaussian blobs with class labels.
    let mut rng = Rng::new(0);
    let n = 400;
    let mut x = Matrix::zeros(n, 2);
    let mut y = Vec::with_capacity(n);
    for r in 0..n {
        let c = (r % 2) as u32;
        let center = if c == 0 { (-2.0f32, 1.0f32) } else { (2.0, -1.0) };
        x.set(r, 0, center.0 + 0.4 * rng.normal_f32());
        x.set(r, 1, center.1 + 0.4 * rng.normal_f32());
        y.push(c);
    }

    // 2. Train: 8 timesteps × 2 classes, K=20 duplication, streaming off.
    let cfg = ForestTrainConfig {
        n_t: 8,
        k_dup: 20,
        params: TrainParams {
            n_trees: 30,
            max_depth: 4,
            kind: TreeKind::Single,
            ..Default::default()
        },
        seed: 1,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = run_training(&cfg, &x, Some(&y), &RunOptions::new().with_workers(2));
    println!(
        "trained {} ensembles in {:.2}s",
        out.report.jobs.len(),
        t0.elapsed().as_secs_f64()
    );

    // 3. Generate with the native backend.
    let gen_cfg = GenerateConfig::new(400, 7);
    let (native, labels) = generate(&out.model, &gen_cfg);
    let w1 = w1_distance(&native, &x, 16, 3);
    println!("native backend:   {} samples, W1(gen, train) = {:.4}", native.rows, w1);
    assert!(w1 < 0.5, "generation should track the training distribution");

    // 4. Generate with the XLA backend (AOT Pallas kernel via PJRT).
    match PjrtRuntime::cpu(Path::new("artifacts")) {
        Ok(runtime) => match XlaField::prepare(&runtime, &out.model) {
            Ok(field) => {
                let (xla_out, xla_labels) = generate_with(&out.model, &field, &gen_cfg);
                let mut max_err = 0.0f32;
                for i in 0..native.data.len() {
                    max_err = max_err.max((native.data[i] - xla_out.data[i]).abs());
                }
                assert_eq!(labels, xla_labels);
                println!(
                    "xla backend:      platform={}, max |native − xla| = {:.2e}",
                    runtime.platform(),
                    max_err
                );
            }
            Err(e) => println!("xla backend:      skipped ({e})"),
        },
        Err(e) => println!("xla backend:      skipped (no PJRT: {e})"),
    }

    // 5. Per-class means — the generated clusters sit where the data is.
    for c in 0..2u32 {
        let rows: Vec<usize> = labels
            .iter()
            .enumerate()
            .filter(|(_, &l)| l == c)
            .map(|(r, _)| r)
            .collect();
        let mean0: f32 =
            rows.iter().map(|&r| native.at(r, 0)).sum::<f32>() / rows.len() as f32;
        let mean1: f32 =
            rows.iter().map(|&r| native.at(r, 1)).sum::<f32>() / rows.len() as f32;
        println!("class {c}: {} samples, mean = ({mean0:.2}, {mean1:.2})", rows.len());
    }
    println!("quickstart OK");
}
