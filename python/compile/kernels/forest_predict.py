"""L1 Pallas kernel: batched packed-forest traversal — the generation hot
spot.

The ensemble is flattened to node tensors ([T trees, N nodes]) with leaves
self-looping, so a fixed `depth` iterations of data-parallel
gather -> compare -> select lands every (row, tree) pair on its leaf; leaf
value vectors are then summed over trees. This is the TPU adaptation of the
paper's inference path (§ Hardware-Adaptation in DESIGN.md): node tables
live in VMEM per tile, rows are tiled by BlockSpec, and the traversal is
gather/VPU work with no MXU involvement.

interpret=True for CPU-PJRT executability; the same kernel structure lowers
to Mosaic for a real TPU target.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _traverse_block(x, feat, thr, left, right, values, depth):
    """Traversal on one row block; pure jnp (runs inside the kernel)."""
    n = x.shape[0]
    t_trees = feat.shape[0]
    node = jnp.zeros((t_trees, n), dtype=jnp.int32)
    rows = jnp.arange(n)
    for _ in range(depth):
        f = jnp.take_along_axis(feat, node, axis=1)
        th = jnp.take_along_axis(thr, node, axis=1)
        xv = x[rows[None, :], f]
        go_left = xv < th
        l = jnp.take_along_axis(left, node, axis=1)
        r = jnp.take_along_axis(right, node, axis=1)
        node = jnp.where(go_left, l, r)
    tree_idx = jnp.arange(t_trees)[:, None]
    leaf_vals = values[tree_idx, node]          # [T, n, m]
    return jnp.sum(leaf_vals, axis=0)           # [n, m]


def make_kernel(depth):
    def kernel(x_ref, feat_ref, thr_ref, left_ref, right_ref, values_ref, o_ref):
        x = x_ref[...]
        feat = feat_ref[...]
        thr = thr_ref[...]
        left = left_ref[...]
        right = right_ref[...]
        values = values_ref[...]
        o_ref[...] = _traverse_block(x, feat, thr, left, right, values, depth)

    return kernel


def forest_accumulate(x, feat, thr, left, right, values, depth,
                      block_n: int = DEFAULT_BLOCK):
    """Sum of leaf-value vectors over the forest for each row of x.

    Shapes: x [n, p]; feat/thr/left/right [T, N]; values [T, N, m].
    Returns [n, m]. `depth` is static.
    """
    n, _p = x.shape
    t_trees, n_nodes = feat.shape
    m = values.shape[2]
    block_n = min(block_n, n)
    grid = (pl.cdiv(n, block_n),)
    return pl.pallas_call(
        make_kernel(depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, x.shape[1]), lambda i: (i, 0)),
            # Tree tensors: one block covering the whole forest, reused by
            # every row tile (the index_map pins block 0).
            pl.BlockSpec((t_trees, n_nodes), lambda i: (0, 0)),
            pl.BlockSpec((t_trees, n_nodes), lambda i: (0, 0)),
            pl.BlockSpec((t_trees, n_nodes), lambda i: (0, 0)),
            pl.BlockSpec((t_trees, n_nodes), lambda i: (0, 0)),
            pl.BlockSpec((t_trees, n_nodes, m), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x, feat, thr, left, right, values)


def vmem_estimate(block_n, p, t_trees, n_nodes, m) -> int:
    """VMEM bytes per grid step: row tile + node tables + value table +
    output tile (f32/i32 = 4 B). The dominant term is the value table
    `T*N*m*4`, which bounds how large a forest fits on-chip per tile."""
    tile = block_n * p * 4
    tables = 4 * t_trees * n_nodes * 4
    values = t_trees * n_nodes * m * 4
    out = block_n * m * 4
    return tile + tables + values + out
