"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel must match its
reference here (pytest + hypothesis sweep shapes), and the Rust native
predictor must match the packed-forest semantics (pinned by the shared AOT
artifact in rust/tests/xla_parity.rs).
"""

import jax.numpy as jnp


def cfm_noising_ref(x0, x1, t):
    """Conditional flow matching forward (Eq. 5): x_t and target.

    x_t = t*x1 + (1-t)*x0 ; z = x1 - x0.
    """
    xt = t * x1 + (1.0 - t) * x0
    z = x1 - x0
    return xt, z


def vp_noising_ref(x0, eps, alpha, sigma):
    """VP-SDE forward (Eq. 2): x_t = alpha*x0 + sigma*eps ; score target
    z = -eps/sigma."""
    xt = alpha * x0 + sigma * eps
    z = -eps / sigma
    return xt, z


def forest_accumulate_ref(x, feat, thr, left, right, values, depth):
    """Sum of leaf values over a packed forest (no eta/base).

    Args:
      x:      [n, p]   float32 batch (NaN-free by contract).
      feat:   [T, N]   int32 split feature per node.
      thr:    [T, N]   float32 split threshold (x < thr goes left).
      left:   [T, N]   int32 left child (leaves self-loop).
      right:  [T, N]   int32 right child (leaves self-loop).
      values: [T, N, m] float32 leaf values (0 on internal/padding nodes).
      depth:  static int — traversal iterations (>= max tree depth).

    Returns: [n, m] sum over trees of values[t, leaf_t(x_i), :].
    """
    n = x.shape[0]
    t_trees = feat.shape[0]
    node = jnp.zeros((t_trees, n), dtype=jnp.int32)
    rows = jnp.arange(n)
    for _ in range(depth):
        f = jnp.take_along_axis(feat, node, axis=1)          # [T, n]
        th = jnp.take_along_axis(thr, node, axis=1)          # [T, n]
        xv = x[rows[None, :], f]                             # [T, n]
        go_left = xv < th
        l = jnp.take_along_axis(left, node, axis=1)
        r = jnp.take_along_axis(right, node, axis=1)
        node = jnp.where(go_left, l, r)
    tree_idx = jnp.arange(t_trees)[:, None]
    leaf_vals = values[tree_idx, node]                       # [T, n, m]
    return jnp.sum(leaf_vals, axis=0)                        # [n, m]


def forest_field_ref(x, feat, thr, left, right, values, base, eta, depth):
    """Full vector field: base + eta * forest_accumulate."""
    acc = forest_accumulate_ref(x, feat, thr, left, right, values, depth)
    return base[None, :] + eta * acc
