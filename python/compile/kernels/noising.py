"""L1 Pallas kernel: fused forward corruption + regression target.

One pass over HBM produces both the noised input x_t and the regression
target — the training-data hot spot that the paper's Issue-1 fix evaluates
on the fly inside every job. On TPU this is a pure VPU streaming kernel;
BlockSpec tiles rows so each [block_n, p] tile of x0/x1 streams
HBM -> VMEM once and writes two output tiles. interpret=True everywhere
(the CPU PJRT plugin cannot run Mosaic custom-calls); the kernel still
lowers to the same fused structure.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _cfm_kernel(x0_ref, x1_ref, t_ref, xt_ref, z_ref):
    x0 = x0_ref[...]
    x1 = x1_ref[...]
    t = t_ref[0]
    xt_ref[...] = t * x1 + (1.0 - t) * x0
    z_ref[...] = x1 - x0


def cfm_noising(x0, x1, t, block_n: int = DEFAULT_BLOCK):
    """Fused CFM forward: returns (x_t, z). `t` is a scalar array."""
    n, p = x0.shape
    block_n = min(block_n, n)
    grid = (pl.cdiv(n, block_n),)
    t_arr = jnp.reshape(t.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _cfm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), jnp.float32),
            jax.ShapeDtypeStruct((n, p), jnp.float32),
        ],
        interpret=True,
    )(x0, x1, t_arr)


def _vp_kernel(x0_ref, eps_ref, coef_ref, xt_ref, z_ref):
    x0 = x0_ref[...]
    eps = eps_ref[...]
    alpha = coef_ref[0]
    sigma = coef_ref[1]
    xt_ref[...] = alpha * x0 + sigma * eps
    z_ref[...] = -eps / sigma


def vp_noising(x0, eps, alpha, sigma, block_n: int = DEFAULT_BLOCK):
    """Fused VP-SDE forward: returns (x_t, score target)."""
    n, p = x0.shape
    block_n = min(block_n, n)
    grid = (pl.cdiv(n, block_n),)
    coef = jnp.stack([alpha.astype(jnp.float32), sigma.astype(jnp.float32)])
    return pl.pallas_call(
        _vp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), jnp.float32),
            jax.ShapeDtypeStruct((n, p), jnp.float32),
        ],
        interpret=True,
    )(x0, eps, coef)


@functools.lru_cache(maxsize=None)
def vmem_estimate(block_n: int, p: int) -> int:
    """Estimated VMEM bytes per grid step (perf model for DESIGN.md §Perf):
    two input tiles + two output tiles + scalars, f32."""
    return (4 * block_n * p * 4) + 16
