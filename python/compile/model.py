"""L2: the JAX compute graphs lowered to AOT artifacts.

Each entry point is a pure function over fixed shapes, calling the L1
Pallas kernels so everything lowers into one HLO module:

* ``forest_field``  — the learned vector field `base + eta * Σ_t leaf_t(x)`
  (the sampler's per-step evaluation; the Euler update itself stays in Rust
  so the same artifact serves flow ODE and diffusion SDE drift).
* ``cfm_noising_graph`` / ``vp_noising_graph`` — fused training-data
  construction (Eq. 5 / Eq. 2).

Python never runs at generation time: these functions exist only to be
lowered by ``aot.py``.
"""

import jax.numpy as jnp

from compile.kernels import forest_predict, noising


def forest_field(x, feat, thr, left, right, values, base, eta, *, depth):
    """The vector field at one (t, y) grid point.

    Returns a 1-tuple (lowered with return_tuple=True for the Rust loader).
    """
    acc = forest_predict.forest_accumulate(x, feat, thr, left, right, values, depth)
    return (base[None, :] + eta * acc,)


def cfm_noising_graph(x0, x1, t):
    """Fused CFM corruption: (x_t, z)."""
    xt, z = noising.cfm_noising(x0, x1, t)
    return (xt, z)


def vp_noising_graph(x0, eps, alpha, sigma):
    """Fused VP-SDE corruption: (x_t, score target)."""
    xt, z = noising.vp_noising(x0, eps, alpha, sigma)
    return (xt, z)


def euler_flow_step(x, feat, thr, left, right, values, base, eta, h, *, depth):
    """One Euler ODE step x <- x - h * field(x) fused end to end (used by
    the fused-sampler ablation in the perf study)."""
    (field,) = forest_field(x, feat, thr, left, right, values, base, eta, depth=depth)
    return (x - h * field,)


def field_input_specs(n, p, t_trees, n_nodes):
    """ShapeDtypeStructs for ``forest_field`` at pinned dims."""
    import jax

    f32 = jnp.float32
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((n, p), f32),                 # x
        jax.ShapeDtypeStruct((t_trees, n_nodes), i32),     # feat
        jax.ShapeDtypeStruct((t_trees, n_nodes), f32),     # thr
        jax.ShapeDtypeStruct((t_trees, n_nodes), i32),     # left
        jax.ShapeDtypeStruct((t_trees, n_nodes), i32),     # right
        jax.ShapeDtypeStruct((t_trees, n_nodes, p), f32),  # values (m = p)
        jax.ShapeDtypeStruct((p,), f32),                   # base
        jax.ShapeDtypeStruct((), f32),                     # eta
    )
