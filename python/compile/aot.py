"""AOT lowering: JAX/Pallas entry points -> HLO TEXT artifacts + index.json.

HLO *text* is the interchange format (NOT ``lowered.compile()`` /
``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts``; a no-op when artifacts are newer than sources.

Usage: python -m compile.aot --out-dir ../artifacts [--report]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import forest_predict, noising


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Pinned artifact shapes. Rust pads models/batches up to these, so one
# artifact serves every model with p matching and trees/nodes/depth below
# the pin. (p must match exactly: it is the feature dimension.)
FIELD_SHAPES = [
    # (name, batch rows, p, trees, nodes, depth)
    ("flow_step_p2", 256, 2, 64, 127, 7),
    ("flow_step_p8", 256, 8, 128, 255, 7),
]
NOISING_SHAPES = [
    # (name, rows, p)
    ("noising_cfm_p8", 256, 8),
    ("noising_vp_p8", 256, 8),
]


def lower_field(n, p, t_trees, n_nodes, depth):
    fn = functools.partial(model.forest_field, depth=depth)
    specs = model.field_input_specs(n, p, t_trees, n_nodes)
    return jax.jit(fn).lower(*specs)


def lower_noising(name, n, p):
    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((n, p), f32)
    s = jax.ShapeDtypeStruct((), f32)
    if "cfm" in name:
        return jax.jit(model.cfm_noising_graph).lower(x, x, s)
    return jax.jit(model.vp_noising_graph).lower(x, x, s, s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--report", action="store_true",
                    help="print the VMEM/roofline perf model per artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    index = {"artifacts": []}
    for name, n, p, t_trees, n_nodes, depth in FIELD_SHAPES:
        lowered = lower_field(n, p, t_trees, n_nodes, depth)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        index["artifacts"].append({
            "name": name, "file": fname, "n": n, "p": p,
            "n_trees": t_trees, "max_nodes": n_nodes, "depth": depth,
        })
        vmem = forest_predict.vmem_estimate(
            forest_predict.DEFAULT_BLOCK, p, t_trees, n_nodes, p)
        print(f"wrote {fname}: {len(text)} chars, VMEM/tile ~ {vmem/1024:.1f} KiB")
        if args.report:
            flops = n * t_trees * depth * 4  # cmp+selects per hop
            bytes_moved = vmem  # tables reload per tile in the worst case
            print(f"  [perf] arithmetic intensity ~ {flops/bytes_moved:.3f} "
                  f"flop/B (gather-bound, VPU-only)")

    for name, n, p in NOISING_SHAPES:
        lowered = lower_noising(name, n, p)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        index["artifacts"].append({
            "name": name, "file": fname, "n": n, "p": p,
            "n_trees": 0, "max_nodes": 0, "depth": 0,
        })
        vmem = noising.vmem_estimate(noising.DEFAULT_BLOCK, p)
        print(f"wrote {fname}: {len(text)} chars, VMEM/tile ~ {vmem/1024:.1f} KiB")
        if args.report:
            # 3 flops / 12 bytes per element for CFM: bandwidth-bound.
            print("  [perf] arithmetic intensity ~ 0.25 flop/B (bandwidth roofline)")

    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=2)
    print(f"index: {len(index['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
