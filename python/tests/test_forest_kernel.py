"""L1 forest-traversal kernel vs oracles.

Random packed forests (valid binary trees with self-looping leaves) are
generated in numpy; the Pallas kernel must match both the jnp reference
and an independent per-row python traversal.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import forest_predict, ref


def random_packed_forest(rng, t_trees, depth, p, m):
    """Build a random full-ish binary forest in packed layout."""
    n_nodes = 2 ** (depth + 1) - 1
    feat = np.zeros((t_trees, n_nodes), dtype=np.int32)
    thr = np.zeros((t_trees, n_nodes), dtype=np.float32)
    left = np.zeros((t_trees, n_nodes), dtype=np.int32)
    right = np.zeros((t_trees, n_nodes), dtype=np.int32)
    values = np.zeros((t_trees, n_nodes, m), dtype=np.float32)
    for t in range(t_trees):
        next_free = [1]

        def build(node, d):
            is_leaf = d >= depth or rng.random() < 0.3 or next_free[0] + 2 > n_nodes
            if is_leaf:
                left[t, node] = node
                right[t, node] = node
                values[t, node] = rng.standard_normal(m).astype(np.float32)
            else:
                l, r = next_free[0], next_free[0] + 1
                next_free[0] += 2
                feat[t, node] = rng.integers(0, p)
                thr[t, node] = rng.standard_normal()
                left[t, node] = l
                right[t, node] = r
                build(l, d + 1)
                build(r, d + 1)

        build(0, 0)
        # Unused padding nodes self-loop.
        for node in range(next_free[0], n_nodes):
            left[t, node] = node
            right[t, node] = node
    return feat, thr, left, right, values


def python_traverse(x, feat, thr, left, right, values, depth):
    """Independent scalar oracle."""
    n = x.shape[0]
    t_trees = feat.shape[0]
    m = values.shape[2]
    out = np.zeros((n, m), dtype=np.float64)
    for i in range(n):
        for t in range(t_trees):
            node = 0
            for _ in range(depth):
                if left[t, node] == node:
                    break
                node = left[t, node] if x[i, feat[t, node]] < thr[t, node] else right[t, node]
            out[i] += values[t, node]
    return out.astype(np.float32)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=80),
    p=st.integers(min_value=1, max_value=10),
    t_trees=st.integers(min_value=1, max_value=12),
    depth=st.integers(min_value=1, max_value=5),
    m=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_both_oracles(n, p, t_trees, depth, m, seed):
    rng = np.random.default_rng(seed)
    feat, thr, left, right, values = random_packed_forest(rng, t_trees, depth, p, m)
    x = rng.standard_normal((n, p)).astype(np.float32)

    out_pallas = np.asarray(
        forest_predict.forest_accumulate(
            jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr),
            jnp.asarray(left), jnp.asarray(right), jnp.asarray(values), depth,
        )
    )
    out_jnp = np.asarray(
        ref.forest_accumulate_ref(
            jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr),
            jnp.asarray(left), jnp.asarray(right), jnp.asarray(values), depth,
        )
    )
    out_py = python_traverse(x, feat, thr, left, right, values, depth)
    np.testing.assert_allclose(out_pallas, out_jnp, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out_pallas, out_py, rtol=1e-4, atol=1e-4)


def test_extra_depth_is_harmless():
    """Iterating deeper than the true depth must not change leaves
    (self-loop invariant — what lets Rust pad depth up to the artifact)."""
    rng = np.random.default_rng(7)
    feat, thr, left, right, values = random_packed_forest(rng, 4, 3, 5, 2)
    x = rng.standard_normal((40, 5)).astype(np.float32)
    args = (jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr),
            jnp.asarray(left), jnp.asarray(right), jnp.asarray(values))
    out3 = np.asarray(forest_predict.forest_accumulate(*args, 3))
    out7 = np.asarray(forest_predict.forest_accumulate(*args, 7))
    np.testing.assert_allclose(out3, out7, rtol=0, atol=0)


def test_inert_padding_trees():
    """All-zero self-loop trees contribute nothing (Rust pads forests up to
    the artifact's tree count)."""
    rng = np.random.default_rng(8)
    feat, thr, left, right, values = random_packed_forest(rng, 3, 3, 4, 2)
    x = rng.standard_normal((20, 4)).astype(np.float32)

    def pad(arr, extra, fill_self_loop=False):
        shape = (extra,) + arr.shape[1:]
        block = np.zeros(shape, dtype=arr.dtype)
        if fill_self_loop:
            n_nodes = arr.shape[1]
            block[:] = np.arange(n_nodes, dtype=arr.dtype)[None, :]
        return np.concatenate([arr, block], axis=0)

    feat_p = pad(feat, 5)
    thr_p = pad(thr, 5)
    left_p = pad(left, 5, fill_self_loop=True)
    right_p = pad(right, 5, fill_self_loop=True)
    values_p = pad(values, 5)
    base = forest_predict.forest_accumulate(
        jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr), jnp.asarray(left),
        jnp.asarray(right), jnp.asarray(values), 3)
    padded = forest_predict.forest_accumulate(
        jnp.asarray(x), jnp.asarray(feat_p), jnp.asarray(thr_p), jnp.asarray(left_p),
        jnp.asarray(right_p), jnp.asarray(values_p), 3)
    # Padding only changes the summation tree -> allow fp reassociation.
    np.testing.assert_allclose(np.asarray(base), np.asarray(padded), rtol=1e-6, atol=1e-6)


def test_vmem_estimate_dominated_by_values():
    small = forest_predict.vmem_estimate(128, 8, 16, 63, 8)
    big = forest_predict.vmem_estimate(128, 8, 128, 255, 8)
    assert big > small * 10
