"""L1 noising kernel vs the pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes and the time parameter; assert_allclose against
ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import noising, ref


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape, dtype=np.float32)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=300),
    p=st.integers(min_value=1, max_value=24),
    t=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_cfm_matches_ref(n, p, t, seed):
    x0 = _rand((n, p), seed)
    x1 = _rand((n, p), seed + 1)
    t_arr = jnp.float32(t)
    xt, z = noising.cfm_noising(jnp.asarray(x0), jnp.asarray(x1), t_arr)
    xt_ref, z_ref = ref.cfm_noising_ref(x0, x1, np.float32(t))
    np.testing.assert_allclose(np.asarray(xt), np.asarray(xt_ref), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=200),
    p=st.integers(min_value=1, max_value=16),
    t=st.floats(min_value=0.05, max_value=1.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_vp_matches_ref(n, p, t, seed):
    # VP-SDE coefficients from the linear beta schedule (matches the Rust
    # forest::schedule::VpSchedule).
    beta_min, beta_max = 0.1, 20.0
    integral = beta_min * t + 0.5 * (beta_max - beta_min) * t * t
    alpha = np.float32(np.exp(-0.5 * integral))
    sigma = np.float32(np.sqrt(max(1.0 - alpha * alpha, 1e-12)))
    x0 = _rand((n, p), seed)
    eps = _rand((n, p), seed + 1)
    xt, z = noising.vp_noising(
        jnp.asarray(x0), jnp.asarray(eps), jnp.float32(alpha), jnp.float32(sigma)
    )
    xt_ref, z_ref = ref.vp_noising_ref(x0, eps, alpha, sigma)
    np.testing.assert_allclose(np.asarray(xt), np.asarray(xt_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=1e-5, atol=1e-5)


def test_cfm_endpoints_exact():
    x0 = _rand((64, 4), 0)
    x1 = _rand((64, 4), 1)
    xt0, _ = noising.cfm_noising(jnp.asarray(x0), jnp.asarray(x1), jnp.float32(0.0))
    xt1, _ = noising.cfm_noising(jnp.asarray(x0), jnp.asarray(x1), jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(xt0), x0, rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(xt1), x1, rtol=0, atol=0)


@pytest.mark.parametrize("block", [1, 7, 64, 128, 500])
def test_block_size_invariance(block):
    """Tiling must not change results (uneven final blocks included)."""
    x0 = _rand((130, 5), 2)
    x1 = _rand((130, 5), 3)
    xt, z = noising.cfm_noising(jnp.asarray(x0), jnp.asarray(x1), jnp.float32(0.3),
                                block_n=block)
    xt_ref, z_ref = ref.cfm_noising_ref(x0, x1, np.float32(0.3))
    np.testing.assert_allclose(np.asarray(xt), np.asarray(xt_ref), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=1e-6, atol=1e-6)


def test_vmem_estimate_monotone():
    assert noising.vmem_estimate(128, 8) < noising.vmem_estimate(128, 16)
    assert noising.vmem_estimate(64, 8) < noising.vmem_estimate(128, 8)
