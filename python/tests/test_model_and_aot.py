"""L2 graph shape/semantics tests + AOT lowering smoke tests."""

import json
import os

import numpy as np

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def _forest_inputs(n=16, p=4, t_trees=3, n_nodes=7, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, p)).astype(np.float32)
    feat = rng.integers(0, p, (t_trees, n_nodes)).astype(np.int32)
    thr = rng.standard_normal((t_trees, n_nodes)).astype(np.float32)
    # Internal node 0 with leaf children 1/2; rest self-loop.
    left = np.tile(np.arange(n_nodes, dtype=np.int32), (t_trees, 1))
    right = left.copy()
    left[:, 0] = 1
    right[:, 0] = 2
    values = rng.standard_normal((t_trees, n_nodes, p)).astype(np.float32)
    values[:, 0, :] = 0.0
    base = rng.standard_normal(p).astype(np.float32)
    return x, feat, thr, left, right, values, base


def test_forest_field_matches_ref():
    x, feat, thr, left, right, values, base = _forest_inputs()
    eta = jnp.float32(0.3)
    (out,) = model.forest_field(
        jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr), jnp.asarray(left),
        jnp.asarray(right), jnp.asarray(values), jnp.asarray(base), eta, depth=3)
    expect = ref.forest_field_ref(
        jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr), jnp.asarray(left),
        jnp.asarray(right), jnp.asarray(values), jnp.asarray(base),
        np.float32(0.3), 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)
    assert out.shape == (16, 4)


def test_euler_flow_step_consistency():
    x, feat, thr, left, right, values, base = _forest_inputs(seed=1)
    eta = jnp.float32(0.3)
    h = jnp.float32(0.1)
    (field,) = model.forest_field(
        jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr), jnp.asarray(left),
        jnp.asarray(right), jnp.asarray(values), jnp.asarray(base), eta, depth=3)
    (stepped,) = model.euler_flow_step(
        jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr), jnp.asarray(left),
        jnp.asarray(right), jnp.asarray(values), jnp.asarray(base), eta, h, depth=3)
    np.testing.assert_allclose(
        np.asarray(stepped), x - 0.1 * np.asarray(field), rtol=1e-5, atol=1e-5)


def test_lowering_produces_hlo_text():
    lowered = aot.lower_field(n=16, p=2, t_trees=4, n_nodes=7, depth=3)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 500
    lowered_n = aot.lower_noising("noising_cfm_small", 16, 2)
    text_n = aot.to_hlo_text(lowered_n)
    assert "HloModule" in text_n


def test_aot_main_writes_index(tmp_path, monkeypatch):
    """End-to-end artifact build at the pinned shapes (slow-ish but the real
    product of the compile path)."""
    out = tmp_path / "artifacts"
    monkeypatch.setattr(
        "sys.argv", ["aot.py", "--out-dir", str(out)])
    aot.main()
    index = json.loads((out / "index.json").read_text())
    names = {a["name"] for a in index["artifacts"]}
    assert {"flow_step_p2", "flow_step_p8", "noising_cfm_p8", "noising_vp_p8"} <= names
    for a in index["artifacts"]:
        path = out / a["file"]
        assert path.exists()
        head = path.read_text()[:200]
        assert "HloModule" in head


def test_lowered_field_executes_like_eager():
    """jit+lower path and eager path agree (catches tracing bugs)."""
    x, feat, thr, left, right, values, base = _forest_inputs(n=8, p=2, seed=3)
    import functools
    fn = functools.partial(model.forest_field, depth=3)
    jitted = jax.jit(fn)
    (eager,) = fn(jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr),
                  jnp.asarray(left), jnp.asarray(right), jnp.asarray(values),
                  jnp.asarray(base), jnp.float32(0.5))
    (jit_out,) = jitted(jnp.asarray(x), jnp.asarray(feat), jnp.asarray(thr),
                        jnp.asarray(left), jnp.asarray(right), jnp.asarray(values),
                        jnp.asarray(base), jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jit_out),
                               rtol=1e-6, atol=1e-6)
